"""Serving control plane: interruptible generation (per-token version
stamps), radix prefix cache (shared prefills, CoW blocks), and admission
scheduling (staleness budget, block accounting)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.async_rl.weights import WeightStore
from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.core.a3po import alpha_from_staleness, staleness
from repro.models import model as M
from repro.rollout.continuous import ContinuousBatchingEngine, Request
from repro.rollout.paged_cache import BlockAllocator
from repro.serving import (
    AdmissionScheduler,
    RadixPrefixCache,
    SchedulerConfig,
    ServingControlPlane,
)
from repro.training.trainer import assemble_train_batch


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, **kw):
    base = dict(max_seqs=2, block_size=4, n_blocks=64, max_blocks_per_seq=8,
                greedy=True)
    base.update(kw)
    return ContinuousBatchingEngine(cfg, **base)


def _prompt(cfg, n=12, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(4, cfg.vocab_size, size=n).astype(np.int32)


# ------------------------------------------------- (a) interruptible stamps
def test_publish_mid_generation_stamps_and_roundtrip(setup):
    """A weight publish mid-decode leaves a visible per-token version
    boundary, and the stamped batch flows through assemble_train_batch ->
    a3po.staleness -> alpha_from_staleness as [B, T]."""
    cfg, params = setup
    store = WeightStore(params, 0)
    eng = _engine(cfg)
    cp = ServingControlPlane(eng, store,
                             AdmissionScheduler(SchedulerConfig(d_max=100)))
    prompt = _prompt(cfg)
    max_new = 8
    cp.submit(prompt, max_new=max_new)
    key = jax.random.PRNGKey(1)
    done = []
    steps = 0
    while not done:
        key, sub = jax.random.split(key)
        done = cp.step(sub)
        steps += 1
        if steps == 4:
            store.publish(params, 2)  # same params, new version: pure stamp
        assert steps < 50
    req = done[0]
    stamps = req.token_versions
    assert len(stamps) == len(req.generated) == len(req.gen_logp)
    # visible boundary: early tokens at v0, later tokens at v2, monotone
    assert stamps[0] == 0 and stamps[-1] == 2
    assert stamps == sorted(stamps)
    assert set(stamps) == {0, 2}
    assert cp.metrics.interrupts == 1

    # round trip into the training stack as [B, T]
    rb = cp.rollout_batch([req], prompt_pad=len(prompt), max_new=max_new)
    assert rb.gen_versions is not None and rb.min_version() == 0
    tb = assemble_train_batch([rb], np.zeros((1,), np.float32))
    T = rb.tokens.shape[1]
    assert tb.versions.shape == (1, T - 1) == tb.behav_logp.shape
    d = staleness(tb.versions, current_version=3)
    alpha = alpha_from_staleness(d, RLConfig())
    assert d.shape == alpha.shape == (1, T - 1)
    # per-token alpha differs across the publish boundary within one seq
    resp = np.asarray(tb.response_mask[0]) > 0
    alphas_on_response = np.unique(np.asarray(alpha[0])[resp])
    assert len(alphas_on_response) == 2  # 1/3 (stale seg) vs 1/1 (fresh seg)
    np.testing.assert_allclose(sorted(alphas_on_response), [1.0 / 3.0, 1.0],
                               rtol=1e-6)
    # behavior logprobs are segment-wise present wherever stamped
    assert np.all(np.asarray(tb.behav_logp[0])[resp] != 0.0)


# ------------------------------------------------- (b) radix prefix sharing
def test_prefix_cache_shares_blocks_and_matches_uncached(setup):
    """The second of two prefix-sharing requests allocates strictly fewer
    fresh blocks than an independent prefill and yields identical logits
    and greedy continuations."""
    cfg, params = setup
    prompt = _prompt(cfg, n=12)  # 3 full blocks at block_size=4
    max_new = 4

    # uncached reference: each admit pays the full allocation
    eng_nc = _engine(cfg)
    free0 = eng_nc.allocator.n_free
    eng_nc.admit_request(params, 0, Request(1, prompt, max_new))
    used_first = free0 - eng_nc.allocator.n_free
    eng_nc.admit_request(params, 1, Request(2, prompt, max_new))
    used_second_uncached = (free0 - used_first) - eng_nc.allocator.n_free
    assert used_second_uncached == used_first == 4  # ceil(16/4)

    # cached: second admit reuses the radix-matched prompt blocks
    eng_c = _engine(cfg)
    eng_c.prefix_cache = RadixPrefixCache(eng_c.allocator,
                                          eng_c.state.block_size)
    cfree0 = eng_c.allocator.n_free
    eng_c.admit_request(params, 0, Request(1, prompt, max_new))
    cused_first = cfree0 - eng_c.allocator.n_free
    eng_c.admit_request(params, 1, Request(2, prompt, max_new))
    cused_second = (cfree0 - cused_first) - eng_c.allocator.n_free
    req2 = eng_c.slots[1]
    # 2 full blocks + 3-token partial overlap with the third (cap at P-1)
    assert req2.prefix_hit_tokens == 11
    assert cused_second < used_second_uncached, (cused_second,
                                                 used_second_uncached)

    # identical logits at the sampling point, cached vs uncached
    np.testing.assert_allclose(np.asarray(eng_c._next_logits[1]),
                               np.asarray(eng_nc._next_logits[1]),
                               rtol=2e-4, atol=2e-4)

    # and identical greedy continuations all the way through
    key = jax.random.PRNGKey(3)
    done_nc, done_c = [], []
    while len(done_c) < 2 or len(done_nc) < 2:
        key, sub = jax.random.split(key)
        done_nc += eng_nc.step(params, sub)
        done_c += eng_c.step(params, sub)
    gen = {r.rid: r.generated for r in done_c}
    gen_ref = {r.rid: r.generated for r in done_nc}
    assert gen == gen_ref


def test_prefix_cache_eviction_restores_allocator(setup):
    """Cache-held references are reclaimable: after release + eviction the
    allocator is back to its initial free count with empty refcounts."""
    cfg, params = setup
    eng = _engine(cfg)
    eng.prefix_cache = RadixPrefixCache(eng.allocator, eng.state.block_size)
    free0 = eng.allocator.n_free
    eng.admit_request(params, 0, Request(1, _prompt(cfg), 4))
    eng.release_slot(0)
    held = eng.prefix_cache.n_cached_blocks
    assert eng.allocator.n_free == free0 - held  # only the cache holds refs
    freed = eng.prefix_cache.evict(held)
    assert freed == held
    assert eng.allocator.n_free == free0
    assert eng.allocator.refcount == {}


# ---------------------------------------------- (c) scheduler + accounting
def test_scheduler_staleness_budget_and_block_release(setup):
    """The scheduler never admits past the staleness budget, and preempted
    sequences return every refcounted block to the allocator."""
    cfg, params = setup
    store = WeightStore(params, 0)
    eng = _engine(cfg)
    sched = AdmissionScheduler(SchedulerConfig(d_max=2,
                                               preempt_action="drop"))
    cp = ServingControlPlane(eng, store, sched, use_prefix_cache=False,
                             resubmit_dropped=False)
    free0 = eng.allocator.n_free
    key = jax.random.PRNGKey(5)

    # (1) queued request past the budget is refused admission, not run
    cp.submit(_prompt(cfg), max_new=4)
    store.publish(params, 5)  # staleness 5 > d_max=2 before admission
    key, sub = jax.random.split(key)
    assert cp.step(sub) == []
    assert cp.metrics.admitted == 0 and cp.metrics.drops == 1
    assert cp.n_inflight == 0
    assert eng.allocator.n_free == free0
    assert eng.allocator.refcount == {}

    # (2) in-flight sequence whose stamps fall behind the budget is
    # preempted and all its blocks come back
    cp.submit(_prompt(cfg), max_new=16)
    key, sub = jax.random.split(key)
    cp.step(sub)  # admits at v5 and decodes one token
    assert cp.n_inflight == 1 and cp.metrics.admitted == 1
    assert eng.allocator.n_free < free0
    store.publish(params, 20)  # 20 - 5 > d_max
    key, sub = jax.random.split(key)
    cp.step(sub)
    assert cp.metrics.preemptions == 1
    assert cp.n_inflight == 0
    assert eng.allocator.n_free == free0
    assert eng.allocator.refcount == {}


def test_scheduler_aging_beats_backpressure_starvation(setup):
    """Under sustained backpressure_high, a non-urgent request used to
    wait forever; with age_promote_s it is promoted to priority 0 after
    aging and admitted despite the hold (and ahead of younger urgent
    arrivals)."""
    cfg, params = setup
    eng = _engine(cfg)
    bulk = Request(1, _prompt(cfg, seed=1), 2, priority=1)
    urgent = Request(2, _prompt(cfg, seed=2), 2, priority=0)

    # without aging: held at backpressure_high for as long as it lasts
    sched = AdmissionScheduler(SchedulerConfig(d_max=100,
                                               backpressure_high=0.5))
    sched.enqueue(bulk, now_s=0.0)
    for t in (0.0, 10.0, 1000.0):
        assert sched.pop_admissible(0, engine=eng, queue_frac=0.8,
                                    now_s=t) is None

    # with aging: promoted to priority 0 once it has waited long enough,
    # which both bypasses the prio>0 hold and outranks younger urgent
    sched = AdmissionScheduler(SchedulerConfig(
        d_max=100, backpressure_high=0.5, age_promote_s=1.0))
    sched.enqueue(bulk, now_s=0.0)
    assert sched.pop_admissible(0, engine=eng, queue_frac=0.8,
                                now_s=0.5) is None  # too young
    sched.enqueue(urgent, now_s=1.5)
    got = sched.pop_admissible(0, engine=eng, queue_frac=0.8, now_s=1.5)
    assert got is not None and got[0].rid == 1  # aged bulk, then urgent
    got2 = sched.pop_admissible(0, engine=eng, queue_frac=0.8, now_s=1.5)
    assert got2 is not None and got2[0].rid == 2


def test_drop_reason_counters(setup):
    """Every drop/preempt carries a reason that lands in the per-reason
    ServingMetrics counters (and therefore in StepRecord.serving)."""
    cfg, params = setup
    store = WeightStore(params, 0)
    eng = _engine(cfg)
    sched = AdmissionScheduler(SchedulerConfig(
        d_max=2, preempt_action="requeue", max_preempts=0))
    cp = ServingControlPlane(eng, store, sched, use_prefix_cache=False,
                             resubmit_dropped=False)
    key = jax.random.PRNGKey(9)

    # (1) budget drop at the admission gate -> drops_staleness_budget
    cp.submit(_prompt(cfg), max_new=4)
    store.publish(params, 5)
    key, sub = jax.random.split(key)
    cp.step(sub)
    assert cp.metrics.drops_staleness_budget == 1
    assert cp.metrics.drops_max_preempts == 0
    drop = cp.dropped_requests[-1]
    assert drop.drop_reason == "staleness_budget"
    assert drop.t_done >= 0  # terminal outcome is stamped

    # (2) staleness preemption with max_preempts=0 -> requeue is over
    # budget immediately -> drops_max_preempts
    cp.submit(_prompt(cfg), max_new=16)
    key, sub = jax.random.split(key)
    cp.step(sub)
    store.publish(params, 20)
    key, sub = jax.random.split(key)
    cp.step(sub)
    assert cp.metrics.preemptions == 1
    assert cp.metrics.preemptions_staleness == 1
    assert cp.metrics.preemptions_slo == 0
    assert cp.metrics.drops_max_preempts == 1
    assert cp.dropped_requests[-1].drop_reason == "max_preempts"

    # the per-reason counters are part of the serving snapshot schema
    snap = cp.metrics.snapshot()
    for reason in ("staleness_budget", "max_preempts", "slo_shed"):
        assert f"drops_{reason}" in snap
    assert snap["drops"] == snap["drops_staleness_budget"] + \
        snap["drops_max_preempts"] + snap["drops_slo_shed"]


def test_scheduler_priority_order(setup):
    """Lower priority class is admitted first regardless of arrival."""
    cfg, params = setup
    store = WeightStore(params, 0)
    eng = _engine(cfg, max_seqs=1)  # one slot: admission order observable
    cp = ServingControlPlane(eng, store,
                             AdmissionScheduler(SchedulerConfig(d_max=100)),
                             use_prefix_cache=False)
    rid_bulk = cp.submit(_prompt(cfg, seed=1), max_new=2, priority=1)
    rid_urgent = cp.submit(_prompt(cfg, seed=2), max_new=2, priority=0)
    key = jax.random.PRNGKey(7)
    order = []
    while len(order) < 2:
        key, sub = jax.random.split(key)
        order += [r.rid for r in cp.step(sub)]
    assert order == [rid_urgent, rid_bulk]
