"""Multi-architecture paged serving: SSM-state cache + hybrid decode.

Pins the PR's core property — mamba2 (pure SSM) and zamba2-style hybrid
stacks decode through the continuous-batching engine (``step_horizon``,
chunked prefill, slot reuse, preemption, publish-resume) with greedy
bit-parity against the whole-sequence ``model.prefill`` +
``model.decode_step`` reference — plus the serving-layer bug-sweep
regressions (scratch-block ``write_token`` routing, admission eviction
accounting, SSM slot-pool lifecycle).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data import tokenizer as tok
from repro.kernels.ssd.kernel import ssd_decode_step_pallas
from repro.kernels.ssd.ref import ssd_decode_step_ref, ssd_sequential_ref
from repro.models import model as M
from repro.models.layers import logits_from_hidden
from repro.rollout import paged_cache as pc
from repro.rollout.continuous import ContinuousBatchingEngine


@pytest.fixture(scope="module")
def ssm_setup():
    cfg = dataclasses.replace(get_config("mamba2-370m-reduced"),
                              dtype="float32")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def hybrid_setup():
    # zamba2-style, shrunk: kinds (ssm, ssm, attn) exercises the shared
    # attention layer without the reduced config's full 6-layer stack
    cfg = dataclasses.replace(get_config("zamba2-1.2b-reduced"),
                              num_layers=3, attn_every=3, dtype="float32")
    assert cfg.block_kinds() == ("ssm", "ssm", "attn")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(2))


def _engine(cfg, **kw):
    base = dict(max_seqs=2, block_size=4, n_blocks=33,
                max_blocks_per_seq=16, greedy=True, decode_horizon=4,
                prefill_chunk=8)
    base.update(kw)
    return ContinuousBatchingEngine(cfg, **base)


def _prompts(cfg, n, seed=0, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, cfg.vocab_size,
                         size=rng.integers(lo, hi)).astype(np.int32)
            for _ in range(n)]


def _ref_greedy(cfg, params, prompt, max_new, publish=None):
    """Whole-sequence reference: prefill + per-token decode_step.

    ``publish``: optional (token_index, new_params) — the decode steps
    from that token boundary on run with the new weights, matching an
    engine that swapped params between horizons.
    """
    toks = jnp.asarray(np.asarray(prompt)[None, :])
    hidden, cache = M.prefill(params, cfg, toks,
                              max_len=len(prompt) + max_new)
    logits = logits_from_hidden(params["embedding"], hidden[:, -1], cfg)
    out = []
    for i in range(max_new):
        if publish is not None and i >= publish[0]:
            params = publish[1]
        t = int(jnp.argmax(logits[0]))
        out.append(t)
        if t == tok.EOS:
            break
        logits, cache = M.decode_step(params, cfg, cache,
                                      jnp.asarray([t]))
    return out


def _run_engine(cfg, params, prompts, max_new, **kw):
    eng = _engine(cfg, **kw)
    rids = [eng.submit(p, max_new=max_new) for p in prompts]
    done = eng.run(params, jax.random.PRNGKey(0))
    assert len(done) == len(prompts)
    by_rid = {r.rid: r.generated for r in done}
    return [by_rid[r] for r in rids], eng


# --------------------------------------------------------------- ssd op
def test_ssd_decode_step_matches_sequential_ref():
    """Iterated O(1) decode steps == the scan over the full sequence."""
    rng = np.random.default_rng(0)
    B, S, nh, hd, ds = 2, 5, 3, 4, 8
    x = jnp.asarray(rng.normal(size=(B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, size=(B, S, nh)), jnp.float32)
    a_log = jnp.asarray(rng.normal(size=(nh,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
    ys_ref, final_ref = ssd_sequential_ref(x, dt, a_log, b, c)
    state = jnp.zeros((B, nh, hd, ds), jnp.float32)
    for t in range(S):
        y, state = ssd_decode_step_ref(state, x[:, t], dt[:, t], a_log,
                                       b[:, t], c[:, t])
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ys_ref[:, t]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(final_ref),
                               atol=1e-5)


def test_ssd_decode_step_pallas_interpret_matches_ref():
    rng = np.random.default_rng(1)
    B, nh, hd, ds = 3, 2, 8, 16
    state = jnp.asarray(rng.normal(size=(B, nh, hd, ds)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, size=(B, nh)), jnp.float32)
    a_log = jnp.asarray(rng.normal(size=(nh,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, ds)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, ds)), jnp.float32)
    y_ref, s_ref = ssd_decode_step_ref(state, x, dt, a_log, b, c)
    y_pl, s_pl = ssd_decode_step_pallas(state, x, dt, a_log, b, c,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref),
                               atol=1e-5)


# ------------------------------------------------------- engine parity
def test_mamba2_engine_matches_reference(ssm_setup):
    """4 prompts through 2 slots (forces slot reuse + SSM state re-zero):
    every generation greedy-matches the whole-sequence reference."""
    cfg, params = ssm_setup
    prompts = _prompts(cfg, 4, seed=3)
    got, eng = _run_engine(cfg, params, prompts, max_new=10)
    for p, g in zip(prompts, got):
        assert g == _ref_greedy(cfg, params, p, 10)
    assert eng.allocator.n_free == 33 - 1  # all pages back (minus scratch)
    assert eng.ssm_pool.n_free == 2        # all SSM slots released
    assert eng.supports_prefix_cache is False


def test_hybrid_engine_matches_reference(hybrid_setup):
    """Hybrid (SSM + shared attention) decode: SSM slots and the paged
    KV pool advance together through chunked prefill + fused horizons."""
    cfg, params = hybrid_setup
    prompts = _prompts(cfg, 4, seed=4, lo=3, hi=13)
    got, eng = _run_engine(cfg, params, prompts, max_new=10)
    for p, g in zip(prompts, got):
        assert g == _ref_greedy(cfg, params, p, 10)
    assert eng.allocator.n_free == 33 - 1
    assert eng.ssm_pool.n_free == 2


def test_hybrid_multiple_attn_layers():
    """attn_every=2 over 4 layers: two shared-attention layers, so the
    attention-position indexing into the KV pool (layer ai) is exercised
    beyond ai=0."""
    cfg = dataclasses.replace(get_config("zamba2-1.2b-reduced"),
                              num_layers=4, attn_every=2, dtype="float32")
    assert cfg.block_kinds() == ("ssm", "attn", "ssm", "attn")
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    prompts = _prompts(cfg, 2, seed=5)
    got, _ = _run_engine(cfg, params, prompts, max_new=8)
    for p, g in zip(prompts, got):
        assert g == _ref_greedy(cfg, params, p, 8)


def test_ssm_preemption_and_slot_reuse_no_stale_state(ssm_setup):
    """Preempting a mid-decode sequence and reusing its SSM slot must not
    leak recurrent state into the next occupant."""
    cfg, params = ssm_setup
    eng = _engine(cfg)
    p0, p1 = _prompts(cfg, 2, seed=6)
    eng.submit(p0, max_new=12)
    eng._admit(params)
    while eng.prefilling_slots():
        eng.prefill_step(params)
    key = jax.random.PRNGKey(0)
    key, sub = jax.random.split(key)
    eng.step_horizon(params, sub)          # decode a few tokens
    [slot] = [s for s, r in eng.slots.items() if r is not None]
    victim = eng.release_slot(slot)        # preempt mid-generation
    assert victim is not None
    assert eng.ssm_pool.n_free == 2
    # the freed slot's next occupant decodes from clean state
    rid = eng.submit(p1, max_new=10)
    done = eng.run(params, jax.random.PRNGKey(7))
    by_rid = {r.rid: r.generated for r in done}
    assert by_rid[rid] == _ref_greedy(cfg, params, p1, 10)
    # and the preempted prompt resubmitted fresh regenerates exactly
    rid2 = eng.submit(p0, max_new=12)
    done2 = eng.run(params, jax.random.PRNGKey(8))
    assert {r.rid: r.generated for r in done2}[rid2] == \
        _ref_greedy(cfg, params, p0, 12)


@pytest.mark.parametrize("setup_name", ["ssm_setup", "hybrid_setup"])
def test_publish_resume_parity(setup_name, request):
    """A weight publish between horizons: tokens decoded after the swap
    match a reference that switches params at the same token boundary
    (carried logits from the old weights sample the boundary token)."""
    cfg, params0 = request.getfixturevalue(setup_name)
    params1 = M.init_params(cfg, jax.random.PRNGKey(99))
    H = 4
    prompt = _prompts(cfg, 1, seed=9)[0]
    eng = _engine(cfg, decode_horizon=H)
    rid = eng.submit(prompt, max_new=3 * H)
    eng._admit(params0)
    while eng.prefilling_slots():
        eng.prefill_step(params0)
    key = jax.random.PRNGKey(3)
    done = []
    for i in range(3):
        key, sub = jax.random.split(key)
        done += eng.step_horizon(params0 if i == 0 else params1, sub)
    gen = {r.rid: r.generated for r in done}[rid]
    assert gen == _ref_greedy(cfg, params0, prompt, 3 * H,
                              publish=(H, params1))


# -------------------------------------------------------- bug-sweep units
def test_ssm_slot_pool_lifecycle():
    pool = pc.SSMSlotPool(2)
    pool.map(0)
    with pytest.raises(AssertionError, match="double map"):
        pool.map(0)
    pool.fork(0, 1)
    assert pool.forks == 1 and pool.n_free == 0
    pool.release(1)
    with pytest.raises(AssertionError, match="unmapped"):
        pool.release(1)
    with pytest.raises(AssertionError, match="fork from unmapped"):
        pool.fork(1, 0)
    assert pool.is_mapped(0) and not pool.is_mapped(1)


def test_write_token_routes_unmapped_to_scratch():
    """A write against an unmapped (-1) block-table entry lands in the
    reserved scratch block (last pool block), never in live block 0."""
    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    state = pc.init_paged_cache(cfg, n_blocks=4, block_size=2, max_seqs=2,
                                max_blocks_per_seq=2)
    # slot 0 mapped to block 0; slot 1 left unmapped with a nonzero len,
    # so its block_idx lookup hits -1
    state = dataclasses.replace(
        state,
        block_tables=jnp.asarray([[0, -1], [-1, -1]], jnp.int32),
        seq_lens=jnp.asarray([0, 1], jnp.int32))
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = jnp.ones((2, kv, hd), jnp.float32)
    out = pc.write_token(state, 0, k, 2 * k, jnp.asarray([0, 1]))
    pool_k = np.asarray(out.pool_k)
    assert pool_k[0, 0, 0].any()          # slot 0's legit write
    assert pool_k[0, 3, 0].any()          # unmapped write -> scratch
    assert not pool_k[0, 0, 1].any()      # block 0 slot-1 offset untouched
    assert not pool_k[0, 1].any() and not pool_k[0, 2].any()


def test_pop_admissible_skips_pointless_eviction():
    """Admission must not destroy cached prefixes for a request that
    cannot be admitted even after full eviction."""
    from repro.serving import AdmissionScheduler, SchedulerConfig
    from repro.serving.prefix_cache import RadixPrefixCache
    from repro.rollout.continuous import Request

    class FakeAllocator:
        def __init__(self):
            self.n_free = 2
            self._refs = {}

        def refs(self, b):
            return self._refs.get(b, 0)

        def incref(self, b):
            self._refs[b] = self._refs.get(b, 0) + 1

        def decref(self, b):
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self.n_free += 1

    class FakeEngine:
        def __init__(self):
            self.allocator = FakeAllocator()
            self.prefix_cache = RadixPrefixCache(self.allocator,
                                                 block_size=2)

        def blocks_needed(self, prompt, max_new):
            return -(-(len(prompt) + max_new) // 2)

    eng = FakeEngine()
    # two cache-only blocks (evictable), two free blocks
    eng.prefix_cache.insert([1, 2, 3, 4], [10, 11])
    assert eng.prefix_cache.evictable_count() == 2
    sched = AdmissionScheduler(SchedulerConfig())
    # needs 8 blocks; 2 free + 2 evictable can never cover it
    sched.enqueue(Request(1, np.arange(12), 4))
    assert sched.pop_admissible(0, engine=eng) is None
    assert eng.prefix_cache.n_cached_blocks == 2      # cache untouched
    assert eng.prefix_cache.evicted_blocks == 0
    # a coverable shortfall (needs 3) does evict and admits (fresh
    # scheduler: the giant request above still blocks the FIFO head)
    sched = AdmissionScheduler(SchedulerConfig())
    sched.enqueue(Request(2, np.arange(4), 2))
    got = sched.pop_admissible(0, engine=eng)
    assert got is not None and got[0].rid == 2
    assert eng.allocator.n_free >= 3


def test_evictable_count_pins_ancestors():
    """An in-use leaf pins its whole chain: only fully-reclaimable
    subtrees count toward what eviction could ever free."""
    from repro.rollout.paged_cache import BlockAllocator
    from repro.serving.prefix_cache import RadixPrefixCache

    alloc = BlockAllocator(8)
    cache = RadixPrefixCache(alloc, block_size=2)
    blocks = alloc.alloc(3)                        # sequence-owned, rc=1
    cache.insert([1, 2, 3, 4, 5, 6], blocks)       # chain of 3 nodes, rc=2
    for b in blocks:
        alloc.decref(b)                            # cache now sole owner
    assert cache.evictable_count() == 3
    # a sequence holds the deepest block -> entire chain pinned
    alloc.incref(blocks[2])
    assert cache.evictable_count() == 0
    alloc.decref(blocks[2])
    # holding only the middle block keeps the leaf evictable
    alloc.incref(blocks[1])
    assert cache.evictable_count() == 1


def test_control_plane_skips_prefix_cache_for_ssm(ssm_setup):
    from repro.async_rl.weights import WeightStore
    from repro.serving import (AdmissionScheduler, SchedulerConfig,
                               ServingControlPlane)
    cfg, params = ssm_setup
    eng = _engine(cfg)
    cp = ServingControlPlane(eng, WeightStore(params, 0),
                             AdmissionScheduler(SchedulerConfig()),
                             use_prefix_cache=True)
    assert eng.prefix_cache is None  # gated off: recurrent state is
    #                                  per-slot, prefixes are unshareable
    cp.submit(_prompts(cfg, 1, seed=10)[0], max_new=4)
    key = jax.random.PRNGKey(0)
    for _ in range(12):
        key, sub = jax.random.split(key)
        cp.step(sub)
        if not cp.n_inflight and not len(cp.scheduler):
            break
    assert cp.metrics.completed == 1
