"""Observability subsystem: tracer, metrics registry, run log, facade.

Covers the PR 6 acceptance surface:
* histogram quantile interpolation + negative-max fix (deterministic;
  the hypothesis properties live in test_obs_properties.py)
* span nesting, thread tracks, flow pairing, Chrome trace schema
* ServingMetrics facade parity (snapshot keys unchanged, registry gauges
  read live state)
* simulate_async smoke: one schema-versioned JSONL record per step
"""
import json
import threading

import pytest

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               get_registry)
from repro.obs.runlog import (RUNLOG_SCHEMA_VERSION, STEP_REQUIRED_KEYS,
                              RunLogger, read_jsonl)
from repro.obs.tracing import (PHASE_SPANS, SpanTracer, install_tracer,
                               phase_breakdown, span, trace_span)


@pytest.fixture
def tracer():
    t = install_tracer(SpanTracer())
    yield t
    install_tracer(None)


# ----------------------------------------------------------------- histogram
class TestHistogram:
    def test_quantile_interpolates_within_bucket(self):
        h = Histogram((0, 10, 20))
        for v in (1, 2, 3, 4, 5, 6, 7, 8):  # all land in (0, 10]
            h.observe(v)
        # p50 target = 4th of 8 obs in bucket (0,10]: 0 + 4/8 * 10 = 5
        assert h.quantile(0.5) == pytest.approx(5.0)
        # the old implementation returned the raw upper bound (10.0)
        assert h.quantile(0.5) < 10.0

    def test_quantile_all_zeros(self):
        h = Histogram((0, 1, 2, 4))
        for _ in range(32):
            h.observe(0.0)
        assert h.quantile(0.5) == pytest.approx(0.0)
        assert h.quantile(0.99) == pytest.approx(0.0)

    def test_quantile_overflow_interpolates_to_max(self):
        h = Histogram((0, 1))
        h.observe(100.0)
        assert h.max == 100.0
        assert 1.0 <= h.quantile(0.5) <= 100.0
        assert h.quantile(1.0) == pytest.approx(100.0)

    def test_negative_max(self):
        h = Histogram((-10, -1, 0, 1))
        h.observe(-5.0)
        h.observe(-2.0)
        assert h.max == pytest.approx(-2.0)  # was 0.0 before the fix

    def test_empty_max_is_zero(self):
        assert Histogram((0, 1)).max == 0.0
        assert Histogram((0, 1)).quantile(0.5) == 0.0

    def test_merge(self):
        a, b = Histogram((0, 1, 2)), Histogram((0, 1, 2))
        for v in (0.5, 1.5):
            a.observe(v)
        for v in (2.5, 0.25):
            b.observe(v)
        a.merge(b)
        assert a.total == 4
        assert a.sum == pytest.approx(4.75)
        assert a.max == pytest.approx(2.5)

    def test_merge_bounds_mismatch(self):
        with pytest.raises(AssertionError):
            Histogram((0, 1)).merge(Histogram((0, 2)))

    def test_snapshot_keys(self):
        s = Histogram((0, 1), name="lat").snapshot()
        assert set(s) == {"lat_mean", "lat_p50", "lat_p99", "lat_max",
                          "lat_count"}


# ------------------------------------------------------------------ registry
class TestRegistry:
    def test_counter_gauge_get_or_create(self):
        r = MetricsRegistry()
        c = r.counter("reqs_total")
        c.inc()
        assert r.counter("reqs_total") is c
        r.gauge("depth").set(3)
        snap = r.snapshot()
        assert snap["reqs_total"] == 1.0
        assert snap["depth"] == 3.0

    def test_labels_make_distinct_children(self):
        r = MetricsRegistry()
        r.counter("hits", engine="a").inc(2)
        r.counter("hits", engine="b").inc(5)
        snap = r.snapshot()
        assert snap['hits{engine="a"}'] == 2.0
        assert snap['hits{engine="b"}'] == 5.0

    def test_kind_collision_raises(self):
        r = MetricsRegistry()
        r.counter("x")
        with pytest.raises(TypeError):
            r.gauge("x")

    def test_callback_gauge_reads_live(self):
        r = MetricsRegistry()
        state = {"v": 1.0}
        r.gauge("live", fn=lambda: state["v"])
        assert r.snapshot()["live"] == 1.0
        state["v"] = 7.0
        assert r.snapshot()["live"] == 7.0

    def test_prometheus_text_cumulative_buckets(self):
        r = MetricsRegistry()
        h = r.histogram("lat", (1.0, 2.0))
        for v in (0.5, 1.5, 5.0):
            h.observe(v)
        text = r.prometheus_text()
        assert '# TYPE lat histogram' in text
        assert 'lat_bucket{le="1"} 1' in text
        assert 'lat_bucket{le="2"} 2' in text
        assert 'lat_bucket{le="+Inf"} 3' in text
        assert Counter and Gauge  # exported names


# -------------------------------------------------------------------- tracer
class TestTracer:
    def test_noop_when_uninstalled(self):
        install_tracer(None)
        s = span("anything", k=1)
        with s as sp:
            sp.set(more=2)  # must not raise

    def test_span_nesting_and_attrs(self, tracer):
        with span("outer", step=0):
            with span("inner") as sp:
                sp.set(tokens=42)
        evs = [e for e in tracer.events() if e["ph"] == "X"]
        names = [e["name"] for e in evs]
        # inner closes first (exit order)
        assert names == ["inner", "outer"]
        inner, outer = evs
        assert inner["args"]["tokens"] == 42
        assert outer["args"]["step"] == 0
        # nesting: inner's interval is contained in outer's
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6

    def test_thread_tracks(self, tracer):
        def work():
            with span("worker_span"):
                pass
        t = threading.Thread(target=work, name="obs-test-worker")
        with span("main_span"):
            t.start()
            t.join()
        evs = tracer.events()
        tids = {e["name"]: e["tid"] for e in evs if e["ph"] == "X"}
        assert tids["main_span"] != tids["worker_span"]
        thread_names = {e["args"]["name"] for e in evs
                        if e["ph"] == "M" and e["name"] == "thread_name"}
        assert "obs-test-worker" in thread_names

    def test_flow_pairing_and_unmatched_end_dropped(self, tracer):
        tracer.flow_end("publish", 99)  # no matching start -> dropped
        with span("publish_span"):
            tracer.flow_start("publish", 7)
        with span("resume_span"):
            tracer.flow_end("publish", 7)
        flows = [(e["ph"], e["id"]) for e in tracer.events()
                 if e["ph"] in ("s", "f")]
        assert flows == [("s", 7), ("f", 7)]

    def test_export_schema(self, tracer, tmp_path):
        with span("a"):
            pass
        tracer.instant("marker", note="x")
        tracer.counter("queue_depth", depth=3)
        path = tracer.export(str(tmp_path / "trace.json"))
        doc = json.loads(open(path).read())
        assert isinstance(doc["traceEvents"], list)
        for ev in doc["traceEvents"]:
            assert ev["ph"] in ("X", "M", "s", "f", "i", "C")
            assert "pid" in ev and "tid" in ev and "name" in ev
            if ev["ph"] == "X":
                assert ev["dur"] > 0 and ev["ts"] >= 0
        from repro.obs.validate import validate_trace
        assert validate_trace(path, expect_spans=["a"]) == []

    def test_trace_span_decorator(self, tracer):
        @trace_span("decorated")
        def f(x):
            return x + 1
        assert f(1) == 2
        assert any(e["name"] == "decorated" for e in tracer.events()
                   if e["ph"] == "X")

    def test_phase_breakdown_counts_only_leaf_spans(self, tracer):
        with span("train_step", step=0):     # wrapper: not a phase span
            with span("train_update"):
                pass
        with span("weight_publish"):
            pass
        phases = phase_breakdown(tracer.events())
        assert set(phases) == {"train", "publish"}
        assert phases["train"]["count"] == 1.0
        assert PHASE_SPANS["decode_horizon"] == "decode"


# ------------------------------------------------------------ serving facade
EXPECTED_SERVING_KEYS = {
    "staleness_mean", "staleness_p50", "staleness_p99", "staleness_max",
    "staleness_count",
    "queue_delay_s_mean", "queue_delay_s_p50", "queue_delay_s_p99",
    "queue_delay_s_max", "queue_delay_s_count",
    "page_util_mean", "page_util_p50", "page_util_p99", "page_util_max",
    "page_util_count",
    "ttft_s_mean", "ttft_s_p50", "ttft_s_p99", "ttft_s_max",
    "ttft_s_count",
    "prefix_hit_rate", "prefix_hit_tokens", "prefill_tokens_computed",
    "prefill_chunks", "prefill_time_s", "prefill_compiles",
    "prefill_tokens_per_s",
    "decode_tokens", "decode_host_syncs", "decode_launches",
    "decode_time_s", "host_syncs_per_token", "decode_tokens_per_s",
    "interrupts", "resumed_sequences", "preemptions",
    "preemptions_staleness", "preemptions_slo", "drops",
    "drops_staleness_budget", "drops_max_preempts", "drops_slo_shed",
    "admitted", "completed", "cow_forks", "oom_sheds", "nan_drops",
}


class TestServingFacade:
    def test_snapshot_keys_preserved(self):
        from repro.serving.metrics import ServingMetrics
        m = ServingMetrics(register=False)
        assert set(m.snapshot()) == EXPECTED_SERVING_KEYS

    def test_registry_gauges_read_live_fields(self):
        from repro.serving.metrics import ServingMetrics
        m = ServingMetrics()  # registers into the global registry
        m.interrupts += 3
        m.decode_tokens = 100
        m.decode_time_s = 2.0
        m.staleness.observe(4.0)
        snap = get_registry().snapshot()
        assert snap["serving_interrupts"] == 3.0
        assert snap["serving_decode_tokens_per_s"] == pytest.approx(50.0)
        assert snap["serving_staleness_count"] == 1.0

    def test_latest_instance_wins(self):
        from repro.serving.metrics import ServingMetrics
        a = ServingMetrics()
        a.drops += 5
        b = ServingMetrics()  # re-registers: registry now reads b
        assert get_registry().snapshot()["serving_drops"] == 0.0
        b.drops += 1
        assert get_registry().snapshot()["serving_drops"] == 1.0

    def test_mutable_dataclass_surface(self):
        from repro.serving.metrics import ServingMetrics
        m = ServingMetrics(register=False)
        m.observe_request(prompt_tokens=10, prefix_hit=4, queue_delay_s=0.01)
        m.observe_finished(staleness_values=[0, 1, 2])
        s = m.snapshot()
        assert s["admitted"] == 1.0
        assert s["completed"] == 1.0
        assert s["prefix_hit_rate"] == pytest.approx(0.4)
        assert s["staleness_count"] == 3.0


# ------------------------------------------------------------------- run log
class TestRunLog:
    def test_step_record_schema(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        with RunLogger(path) as log:
            log.log_event("meta", arch="toy-2m")
            log.log_step({"step": 0, "reward": 0.5, "loss": 0.1,
                          "staleness_mean": 1.0, "rollout_time_s": 0.2,
                          "train_time_s": 0.3, "wall_time_s": 0.6,
                          "serving": {"drops": 0}})
        steps = read_jsonl(path)
        assert len(steps) == 1
        rec = steps[0]
        assert rec["schema"] == RUNLOG_SCHEMA_VERSION
        for k in STEP_REQUIRED_KEYS:
            assert k in rec, k
        assert rec["serving"] == {"drops": 0}
        metas = read_jsonl(path, kind="meta")
        assert metas[0]["arch"] == "toy-2m"

    def test_missing_required_key_asserts(self, tmp_path):
        log = RunLogger(str(tmp_path / "r.jsonl"))
        with pytest.raises(AssertionError):
            log.log_step({"step": 0})
        log.close()

    def test_quiet_suppresses_stdout(self, capsys):
        log = RunLogger(None, quiet=True)
        log.print("should not appear")
        assert capsys.readouterr().out == ""
        log2 = RunLogger(None)
        log2.print("visible")
        assert "visible" in capsys.readouterr().out


# ------------------------------------------------------- orchestrator smoke
class TestOrchestratorSmoke:
    def test_simulate_async_one_record_per_step(self, tmp_path):
        from repro.async_rl.orchestrator import simulate_async
        from repro.configs.base import RLConfig
        from repro.configs.registry import get_config
        from repro.data.tasks import ArithmeticTask

        cfg = get_config("toy-2m")
        rl = RLConfig(group_size=2)
        jsonl = str(tmp_path / "run.jsonl")
        trace = str(tmp_path / "trace.json")
        tracer = install_tracer(SpanTracer())
        try:
            with RunLogger(jsonl, quiet=True) as log:
                simulate_async(cfg, rl, ArithmeticTask(max_operand=9),
                               "a3po", num_steps=3, n_prompts=2,
                               max_new_tokens=4, staleness=1,
                               run_logger=log)
                assert log.steps_logged == 3
            tracer.export(trace)
        finally:
            install_tracer(None)

        steps = read_jsonl(jsonl)
        assert [r["step"] for r in steps] == [0, 1, 2]
        assert all(r["schema"] == RUNLOG_SCHEMA_VERSION for r in steps)

        from repro.obs.validate import validate_jsonl, validate_trace
        assert validate_jsonl(jsonl, min_steps=3) == []
        assert validate_trace(trace, expect_spans=[
            "rollout_generate", "train_update", "weight_publish"]) == []

        names = {e["name"] for e in json.load(open(trace))["traceEvents"]
                 if e["ph"] == "X"}
        assert {"rollout", "train_step"} <= names
        phases = phase_breakdown(json.load(open(trace))["traceEvents"])
        assert {"rollout", "train", "publish"} <= set(phases)
