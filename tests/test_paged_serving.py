"""Paged KV cache + continuous batching: equivalence with the dense-cache
engine and allocator invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.models import model as M
from repro.rollout import paged_cache as pc
from repro.rollout.continuous import ContinuousBatchingEngine
from repro.rollout.engine import RolloutEngine


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_allocator_invariants():
    a = pc.BlockAllocator(8)
    blocks = a.alloc(5)
    assert len(set(blocks)) == 5 and a.n_free == 3
    a.release(blocks[:2])
    assert a.n_free == 5
    with pytest.raises(RuntimeError):
        a.alloc(6)


def test_paged_greedy_matches_dense_engine(setup):
    """Continuous-batching greedy decode == the dense-cache rollout engine
    for every request, despite requests sharing the pool."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7, 12)]
    max_new = 6

    # reference: dense engine, one at a time (greedy)
    engine = RolloutEngine(cfg, RLConfig(), max_new_tokens=max_new)
    ref = []
    for p in prompts:
        rb = engine.generate(params, p[None, :],
                             np.array([len(p)], np.int32),
                             jax.random.PRNGKey(1), greedy=True)
        n_emitted = int(rb.gen_mask[0].sum())
        ref.append(list(rb.tokens[0, len(p): len(p) + n_emitted]))

    # paged continuous batching (2 slots for 4 requests => slot reuse)
    srv = ContinuousBatchingEngine(cfg, max_seqs=2, block_size=4,
                                   n_blocks=32, max_blocks_per_seq=8,
                                   greedy=True)
    for p in prompts:
        srv.submit(p, max_new=max_new)
    done = srv.run(params, jax.random.PRNGKey(2))
    assert len(done) == len(prompts)
    by_rid = {r.rid: r for r in done}
    for i, p in enumerate(prompts):
        got = by_rid[i + 1].generated
        # trim PAD-after-EOS differences: compare up to reference length
        assert got[: len(ref[i])] == [int(t) for t in ref[i]], (
            i, got, ref[i])
    # all pages returned to the pool
    assert srv.allocator.n_free == 32 - 1  # minus the reserved scratch


def test_paged_write_gather_roundtrip(setup):
    cfg, params = setup
    state = pc.init_paged_cache(cfg, n_blocks=8, block_size=4, max_seqs=2,
                                max_blocks_per_seq=4, dtype=jnp.float32)
    alloc = pc.BlockAllocator(8)
    state = pc.map_sequence(state, alloc, slot=0, n_tokens=6)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    writes = []
    for t in range(6):
        k = jnp.full((1, kv, hd), float(t + 1))
        v = -k
        state = pc.write_token(state, 0, k, v, jnp.array([0]))
        state = pc.bump_lens(state, jnp.array([0]))
        writes.append(float(t + 1))
    kk, vv, valid = pc.gather_kv(state, 0, jnp.array([0]))
    assert int(valid[0].sum()) == 6
    got = np.asarray(kk[0, :6, 0, 0])
    np.testing.assert_allclose(got, writes)
    np.testing.assert_allclose(np.asarray(vv[0, :6, 0, 0]), [-w for w in writes])
