"""Chaos suite for the fault-tolerant async runtime (repro.resilience).

Seeded fault plans drive every scenario, so each test is exactly
reproducible: crash-consistent checkpoints (torn-pair detection,
newest-valid fallback), bit-exact crash->resume parity, supervised
worker restarts with zero trainer deadlock, on-device non-finite guards,
weight-publish retries, and serving graceful degradation (KV-pool shed,
NaN-logit quarantine).
"""
import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import textwrap
import threading
import time

import jax
import numpy as np
import pytest

from repro.async_rl.buffer import QueueClosed, RolloutQueue
from repro.async_rl.weights import WeightStore
from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.data.tasks import ArithmeticTask
from repro.resilience import (
    CheckpointManager,
    DivergenceDetector,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    PublishError,
    ResilienceConfig,
    ResilientPublisher,
    SupervisedWorker,
    TrainGuard,
    WorkerFailed,
    parse_fault,
    pop_with_health,
)
from repro.rollout.engine import RolloutBatch
from repro.training.checkpoints import (
    CheckpointError,
    load_checkpoint,
    save_checkpoint,
)
from repro.training.trainer import Trainer, assemble_train_batch


@pytest.fixture(scope="module")
def toy():
    return dataclasses.replace(get_config("toy-2m"), dtype="float32")


@pytest.fixture(scope="module")
def rl():
    return RLConfig(group_size=2, num_minibatches=1, learning_rate=2e-4,
                    max_staleness=3)


def _task():
    return ArithmeticTask(max_operand=9, n_terms=2, prompt_len=8, seed=0)


def _mk_batch(version):
    return RolloutBatch(np.zeros((1, 4), np.int32), np.array([2]),
                        np.zeros((1, 2), np.float32),
                        np.ones((1, 2), np.float32), version=version)


# ------------------------------------------------------------- fault plane
class TestFaultPlan:
    def test_parse_grammar(self):
        s = parse_fault("rollout_crash@3")
        assert (s.kind, s.at, s.times, s.magnitude) == \
            ("rollout_crash", 3, 1, 0.0)
        s = parse_fault("kv_exhaust@5x3:64")
        assert (s.kind, s.at, s.times, s.magnitude) == \
            ("kv_exhaust", 5, 3, 64.0)
        s = parse_fault("queue_stall@2:0.25")
        assert (s.kind, s.at, s.times, s.magnitude) == \
            ("queue_stall", 2, 1, 0.25)
        assert parse_fault(s.spec_str()) == s

    def test_parse_rejects_garbage(self):
        with pytest.raises(ValueError):
            parse_fault("no_at_sign")
        with pytest.raises(ValueError):
            parse_fault("unknown_kind@0")
        with pytest.raises(ValueError):
            FaultSpec("train_crash", at=-1)
        with pytest.raises(ValueError):
            FaultSpec("train_crash", at=0, times=0)

    def test_occurrence_window(self):
        plan = FaultPlan([FaultSpec("train_crash", at=2, times=2)])
        hits = [plan.check("train_crash") is not None for _ in range(6)]
        assert hits == [False, False, True, True, False, False]
        assert plan.occurrences("train_crash") == 6
        assert [f["occurrence"] for f in plan.fired] == [2, 3]

    def test_sites_are_independent(self):
        plan = FaultPlan([FaultSpec("nan_grad", at=0)])
        assert plan.check("rollout_crash") is None  # different site
        assert plan.check("nan_grad") is not None

    def test_maybe_crash_raises(self):
        plan = FaultPlan.from_strings(["train_crash@1"])
        plan.maybe_crash("train_crash")  # occurrence 0: healthy
        with pytest.raises(InjectedFault) as ei:
            plan.maybe_crash("train_crash")
        assert ei.value.occurrence == 1

    def test_seeded_rng_deterministic(self):
        a = FaultPlan([], seed=7).rng.integers(1000, size=5)
        b = FaultPlan([], seed=7).rng.integers(1000, size=5)
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------ atomic checkpoints
class TestAtomicCheckpoint:
    def test_roundtrip_and_checksum(self, tmp_path):
        path = str(tmp_path / "ck")
        tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                "nested": {"b": np.ones((3,), np.float32)}}
        save_checkpoint(path, tree, {"step": 4})
        out, meta = load_checkpoint(path)
        assert meta == {"step": 4}  # format keys stripped
        np.testing.assert_array_equal(out["w"], tree["w"])
        # no staging litter left behind
        assert not [n for n in os.listdir(tmp_path)
                    if n.startswith(".ckpt-tmp")]

    def test_torn_npz_detected(self, tmp_path):
        path = str(tmp_path / "ck")
        save_checkpoint(path, {"w": np.ones((8, 8), np.float32)}, {})
        with open(path + ".npz", "r+b") as f:
            f.seek(60)
            f.write(b"\xde\xad\xbe\xef")
        with pytest.raises(CheckpointError, match="checksum"):
            load_checkpoint(path)

    def test_missing_pieces_detected(self, tmp_path):
        path = str(tmp_path / "ck")
        save_checkpoint(path, {"w": np.ones(3, np.float32)}, {})
        os.unlink(path + ".json")
        with pytest.raises(CheckpointError):
            load_checkpoint(path)
        with pytest.raises(CheckpointError):
            load_checkpoint(str(tmp_path / "never-saved"))


class TestCheckpointManager:
    def test_save_restore_full_capture(self, toy, rl, tmp_path):
        trainer = Trainer(toy, rl)
        state = trainer.init_state(jax.random.PRNGKey(0))
        task = _task()
        task.sample(3)  # advance the RNG so the state is non-trivial
        mgr = CheckpointManager(str(tmp_path))
        key = jax.random.PRNGKey(42)
        mgr.save(2, state, key=key,
                 history=[(state.params, 0)],
                 task_rng_state=task.rng.bit_generator.state,
                 extra={"algo": "a3po"})
        info = mgr.restore_latest()
        assert info is not None and info.step == 2
        assert info.metadata["algo"] == "a3po"
        np.testing.assert_array_equal(np.asarray(info.key), np.asarray(key))
        assert len(info.history) == 1 and info.history[0][1] == 0
        # restored task RNG continues the same stream
        fresh = _task()
        fresh.rng.bit_generator.state = info.task_rng_state
        np.testing.assert_array_equal(fresh.sample(2).prompts,
                                      task.sample(2).prompts)
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(info.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_pointer_and_retention(self, toy, rl, tmp_path):
        state = Trainer(toy, rl).init_state(jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for step in (1, 2, 3, 4):
            mgr.save(step, state)
        assert mgr.latest_step() == 4
        kept = sorted(n for n in os.listdir(tmp_path)
                      if n.endswith(".json") and n != "latest")
        assert kept == ["step_00000003.json", "step_00000004.json"]

    def test_corrupt_newest_falls_back(self, toy, rl, tmp_path):
        state = Trainer(toy, rl).init_state(jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, state)
        mgr.save(2, state)
        # tear the newest checkpoint's npz (simulated mid-write crash)
        with open(mgr.path_for(2) + ".npz", "r+b") as f:
            f.seek(40)
            f.write(b"\x00" * 16)
        info = mgr.restore_latest()
        assert info is not None and info.step == 1

    def test_empty_dir_returns_none(self, tmp_path):
        assert CheckpointManager(str(tmp_path)).restore_latest() is None


# ------------------------------------------------------------ rollout queue
class TestRolloutQueueTimeouts:
    def test_pop_timeout_raises(self):
        q = RolloutQueue(capacity=2, max_staleness=2)
        with pytest.raises(TimeoutError):
            q.pop(timeout=0.05)
        with pytest.raises(TimeoutError):
            q.pop_fresh(current_version=0, n=1, timeout=0.05)

    def test_close_wakes_blocked_consumer(self):
        q = RolloutQueue(capacity=2, max_staleness=2)
        err = []

        def consumer():
            try:
                q.pop(timeout=30.0)
            except QueueClosed as e:
                err.append(e)

        t = threading.Thread(target=consumer)
        t.start()
        time.sleep(0.05)
        q.close()
        t.join(timeout=5.0)
        assert not t.is_alive() and len(err) == 1

    def test_closed_queue_still_drains_pending(self):
        q = RolloutQueue(capacity=2, max_staleness=2)
        q.push(_mk_batch(0))
        q.close()
        assert q.pop(timeout=0.5).version == 0
        with pytest.raises(QueueClosed):
            q.pop(timeout=0.5)
        with pytest.raises(QueueClosed):
            q.push(_mk_batch(1))

    def test_pop_fresh_deadline_spans_stale_drops(self):
        """Stale batches must not reset the clock: the whole call is
        bounded by one deadline."""
        q = RolloutQueue(capacity=4, max_staleness=1)
        q.push(_mk_batch(0))  # stale at current_version=5
        t0 = time.perf_counter()
        with pytest.raises(TimeoutError):
            q.pop_fresh(current_version=5, n=1, timeout=0.2)
        assert time.perf_counter() - t0 < 5.0
        assert q.dropped == 1


# --------------------------------------------------------------- supervisor
class TestSupervisedWorker:
    def test_crash_restart_then_succeed(self):
        calls = []

        def body(ctx):
            calls.append(1)
            if len(calls) < 3:
                raise RuntimeError("boom")
            while not ctx.should_stop():
                ctx.heartbeat()
                time.sleep(0.01)

        w = SupervisedWorker("t", body, max_restarts=5,
                             backoff_base_s=0.01, backoff_max_s=0.02)
        w.start()
        time.sleep(0.5)
        assert w.alive and not w.failed
        assert w.restarts == 2 and len(w.crashes) == 2
        assert w.health_error() is None
        assert w.crashes[0].recovery_s >= 0.0  # MTTR sample recorded
        w.stop()
        assert not w.alive

    def test_budget_exhaustion_flags_failed(self):
        def body(ctx):
            raise ValueError("always broken")

        w = SupervisedWorker("t", body, max_restarts=2,
                             backoff_base_s=0.005, backoff_max_s=0.01)
        w.start()
        deadline = time.time() + 5.0
        while not w.failed and time.time() < deadline:
            time.sleep(0.01)
        assert w.failed and w.restarts == 2 and len(w.crashes) == 3
        assert "failed permanently" in w.health_error()
        assert w.last_crash.exc_type == "ValueError"

    def test_pop_with_health_never_deadlocks_on_dead_producer(self):
        """Regression: a killed worker used to leave the trainer blocked
        in queue.pop forever. Now the consumer raises WorkerFailed."""
        q = RolloutQueue(capacity=2, max_staleness=2)

        def body(ctx):
            raise RuntimeError("producer died instantly")

        w = SupervisedWorker("dead", body, max_restarts=0)
        w.start()
        t0 = time.perf_counter()
        with pytest.raises(WorkerFailed, match="failed permanently"):
            pop_with_health(q, w, current_version=0, poll_s=0.05,
                            deadline_s=30.0)
        assert time.perf_counter() - t0 < 10.0
        w.stop()

    def test_pop_with_health_deadline(self):
        q = RolloutQueue(capacity=2, max_staleness=2)
        with pytest.raises(TimeoutError):
            pop_with_health(q, None, current_version=0, poll_s=0.05,
                            deadline_s=0.15)


# ------------------------------------------------------------------- guards
class TestGuards:
    def test_divergence_detector(self):
        det = DivergenceDetector(window=8, threshold_sigmas=4.0,
                                 min_window=4)
        for _ in range(8):
            assert not det.update(1.0 + 0.01 * np.random.default_rng(0)
                                  .standard_normal())
        assert det.update(100.0)
        assert det.update(float("nan"))

    def test_guard_policies(self):
        g = TrainGuard(policy="skip")
        ok = g.after_step({"loss": 1.0, "nonfinite": 0.0})
        assert ok.action == "ok"
        v = g.after_step({"loss": float("nan"), "nonfinite": 2.0})
        # counts skipped *minibatches*, not steps
        assert v.action == "skip" and g.skipped_updates == 2
        g2 = TrainGuard(policy="rollback")
        v2 = g2.after_step({"loss": float("nan"), "nonfinite": 1.0})
        assert v2.action == "rollback" and g2.rollbacks == 1

    def test_on_device_skip_keeps_params_bit_identical(self, toy, rl):
        """A NaN reward poisons loss + every grad leaf; with the guard the
        packed-metrics step must leave params and Adam state exactly
        unchanged (jnp.where on device, no extra host sync) and count the
        skipped minibatch. Without it, params go non-finite."""
        from repro.rollout.engine import RolloutEngine
        task = _task()
        engine = RolloutEngine(toy, rl, max_new_tokens=3)
        guarded = Trainer(toy, rl, "loglinear", skip_nonfinite=True)
        state = guarded.init_state(jax.random.PRNGKey(0))
        batch = task.sample(2)
        prompts = np.repeat(batch.prompts, rl.group_size, axis=0)
        lengths = np.repeat(batch.prompt_lengths, rl.group_size)
        rb = engine.generate(state.params, prompts, lengths,
                             jax.random.PRNGKey(1), version=0)
        rewards = np.full((prompts.shape[0],), np.nan, np.float32)
        tb = assemble_train_batch([rb], rewards)

        before = [np.asarray(x) for x in jax.tree.leaves(state.params)]
        opt_before = [np.asarray(x) for x in jax.tree.leaves(state.opt)]
        state2, m = guarded.step(state, tb)
        assert m["nonfinite"] >= 1.0
        for a, b in zip(before, jax.tree.leaves(state2.params)):
            np.testing.assert_array_equal(a, np.asarray(b))
        for a, b in zip(opt_before, jax.tree.leaves(state2.opt)):
            np.testing.assert_array_equal(a, np.asarray(b))

        unguarded = Trainer(toy, rl, "loglinear")
        state3, m3 = unguarded.step(state, tb)
        assert not all(np.isfinite(np.asarray(leaf)).all()
                       for leaf in jax.tree.leaves(state3.params))


# ------------------------------------------------------------ sim chaos
class TestSimulatorChaos:
    def test_crash_resume_bit_exact(self, toy, rl, tmp_path):
        """Kill mid-training at a fault-plan step; `--resume auto`
        semantics restore params/opt/RNG/staleness-history and the run
        finishes bit-identical to an uninterrupted one."""
        from repro.async_rl.orchestrator import simulate_async
        steps, every, crash_at = 6, 2, 5

        res_a = ResilienceConfig(
            checkpointer=CheckpointManager(str(tmp_path / "a")),
            ckpt_every=every)
        state_a, recs_a = simulate_async(
            toy, rl, _task(), "loglinear", steps, n_prompts=2,
            max_new_tokens=3, staleness=1, seed=0, resilience=res_a)
        assert recs_a[-1].resilience[
            "resilience_checkpoint_saves_total"] >= 3

        res_b = ResilienceConfig(
            checkpointer=CheckpointManager(str(tmp_path / "b")),
            ckpt_every=every,
            faults=FaultPlan.from_strings([f"train_crash@{crash_at}"]))
        with pytest.raises(InjectedFault):
            simulate_async(toy, rl, _task(), "loglinear", steps,
                           n_prompts=2, max_new_tokens=3, staleness=1,
                           seed=0, resilience=res_b)

        res_c = ResilienceConfig(
            checkpointer=CheckpointManager(str(tmp_path / "b")),
            ckpt_every=every)
        resume = res_c.checkpointer.restore_latest()
        assert resume is not None and resume.step == 4
        state_b, recs_b = simulate_async(
            toy, rl, _task(), "loglinear", steps, n_prompts=2,
            max_new_tokens=3, staleness=1, seed=0, resilience=res_c,
            resume=resume)
        assert [r.step for r in recs_b] == [4, 5]
        for a, b in zip(jax.tree.leaves(state_a.params),
                        jax.tree.leaves(state_b.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state_a.opt),
                        jax.tree.leaves(state_b.opt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_nan_grad_fault_with_guard(self, toy, rl):
        from repro.async_rl.orchestrator import simulate_async
        res = ResilienceConfig(
            faults=FaultPlan.from_strings(["nan_grad@1"]),
            guard=TrainGuard(policy="skip"))
        state, recs = simulate_async(
            toy, rl, _task(), "loglinear", 3, n_prompts=2,
            max_new_tokens=3, staleness=1, seed=0, resilience=res)
        assert res.guard.skipped_updates == 1
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree.leaves(state.params))
        snap = recs[-1].resilience
        assert snap['resilience_faults_injected_total{kind="nan_grad"}'] \
            >= 1.0


# ------------------------------------------------------- async orchestrator
class TestAsyncChaos:
    def test_rollout_crash_restarted_no_deadlock(self, toy, rl):
        """An injected rollout-worker crash is restarted by the
        supervisor; the trainer never deadlocks and every step completes.
        Fault + restart counters surface in StepRecord.resilience."""
        from repro.async_rl.orchestrator import AsyncOrchestrator
        res = ResilienceConfig(
            faults=FaultPlan.from_strings(["rollout_crash@1"]),
            max_worker_restarts=3, pop_deadline_s=60.0)
        orch = AsyncOrchestrator(toy, rl, _task(), "loglinear",
                                 n_prompts=2, max_new_tokens=3,
                                 queue_capacity=2, resilience=res)
        trainer = Trainer(toy, rl, "loglinear")
        state = trainer.init_state(jax.random.PRNGKey(0))
        state, recs = orch.run(state, num_steps=3)
        assert len(recs) == 3 and int(state.version) == 3
        assert len(orch.worker.crashes) == 1
        assert orch.worker.restarts == 1 and not orch.worker.failed
        snap = recs[-1].resilience
        assert snap["resilience_worker_restarts_total"] >= 1.0
        assert snap[
            'resilience_faults_injected_total{kind="rollout_crash"}'] >= 1.0
        assert orch.queue.closed  # clean shutdown propagated

    def test_dead_producer_surfaces_worker_failed(self, toy, rl):
        """Worker crashes past its restart budget -> the trainer's pop
        raises WorkerFailed promptly instead of hanging."""
        from repro.async_rl.orchestrator import AsyncOrchestrator
        res = ResilienceConfig(
            faults=FaultPlan.from_strings(["rollout_crash@0x16"]),
            max_worker_restarts=1, pop_deadline_s=60.0)
        orch = AsyncOrchestrator(toy, rl, _task(), "loglinear",
                                 n_prompts=2, max_new_tokens=3,
                                 queue_capacity=2, resilience=res)
        state = Trainer(toy, rl, "loglinear").init_state(
            jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        with pytest.raises(WorkerFailed):
            orch.run(state, num_steps=2)
        assert time.perf_counter() - t0 < 60.0
        assert orch.worker.failed


# ----------------------------------------------------------------- publish
class TestPublishResilience:
    def test_retry_then_recover(self, toy):
        from repro.models import model as M
        params = M.init_params(toy, jax.random.PRNGKey(0))
        store = WeightStore(params, 0)
        pub = ResilientPublisher(
            store, faults=FaultPlan.from_strings(["publish_fail@0x2"]),
            max_retries=5, backoff_base_s=0.001, backoff_max_s=0.002)
        attempts = pub.publish(params, 1)
        assert attempts == 3 and store.version == 1
        assert pub.retries == 2 and pub.failures == 0

    def test_budget_exhausted_raises_store_untouched(self, toy):
        from repro.models import model as M
        params = M.init_params(toy, jax.random.PRNGKey(0))
        store = WeightStore(params, 0)
        pub = ResilientPublisher(
            store, faults=FaultPlan.from_strings(["publish_fail@0x99"]),
            max_retries=2, backoff_base_s=0.001, backoff_max_s=0.002)
        with pytest.raises(PublishError):
            pub.publish(params, 1)
        # old version keeps serving — the store never saw the new one
        assert store.version == 0 and pub.failures == 1


# ---------------------------------------------------- serving degradation
class TestServingDegradation:
    def _cp(self, cfg, params, *, faults=None, n_blocks=16, max_seqs=2,
            max_new=8):
        from repro.rollout.continuous import ContinuousBatchingEngine
        from repro.serving import (AdmissionScheduler, SchedulerConfig,
                                   ServingControlPlane)
        eng = ContinuousBatchingEngine(
            cfg, max_seqs=max_seqs, block_size=4, n_blocks=n_blocks,
            max_blocks_per_seq=8, greedy=True)
        cp = ServingControlPlane(
            eng, WeightStore(params, 0),
            AdmissionScheduler(SchedulerConfig(d_max=100,
                                               max_preempts=100)),
            use_prefix_cache=False, faults=faults)
        return eng, cp

    def test_kv_exhaust_sheds_instead_of_oom(self, toy):
        """Starve the block pool mid-decode: the control plane sheds a
        sequence through the scheduler (and later finishes it) instead of
        the allocator raising mid-CoW-fork.

        The engine pre-maps a sequence's full extent at admission, so the
        only organic decode-time allocation is a copy-on-write fork of a
        radix-shared write block. We set up exactly that state — an extra
        reference on the next write block, as the prefix cache holds on
        matched prompt blocks — while the kv_exhaust fault takes the free
        pool hostage."""
        from repro.models import model as M
        from repro.rollout import paged_cache as pc
        params = M.init_params(toy, jax.random.PRNGKey(0))
        faults = FaultPlan.from_strings(["kv_exhaust@3x5:99"])
        eng, cp = self._cp(toy, params, faults=faults, n_blocks=13)
        rng = np.random.default_rng(0)
        for _ in range(2):
            cp.submit(rng.integers(4, toy.vocab_size, 12).astype(np.int32),
                      max_new=8)
        key = jax.random.PRNGKey(0)
        done = 0
        for _ in range(3):  # warm up: both sequences mid-generation
            key, sub = jax.random.split(key)
            done += len(cp.step(sub))
        assert done == 0
        # mimic a radix-shared write block on slot 0: its next decode
        # write needs a CoW fork (1 fresh block) — but the fault is about
        # to grab the entire free pool
        first, _ = pc.write_range(int(eng._lens[0]), 1,
                                  eng.state.block_size, eng.state.max_blocks)
        eng.allocator.incref(int(eng._tables[0, first]))
        for _ in range(200):
            key, sub = jax.random.split(key)
            done += len(cp.step(sub))
            if done == 2:
                break
        assert done == 2                      # everything still finishes
        assert cp.metrics.oom_sheds >= 1      # via the shed path
        assert cp._kv_holds == []             # fault released its hostages

    def test_nan_logits_quarantined(self, toy):
        """A poisoned decode row must never leak non-finite logprobs into
        rollout data: the finished request is dropped + resubmitted."""
        from repro.models import model as M
        params = M.init_params(toy, jax.random.PRNGKey(0))
        # max_seqs=1 -> the poisoned row is always the active slot
        faults = FaultPlan.from_strings(["nan_logits@1"])
        eng, cp = self._cp(toy, params, faults=faults, n_blocks=32,
                           max_seqs=1)
        prompt = np.random.default_rng(0).integers(
            4, toy.vocab_size, 8).astype(np.int32)
        rid = cp.submit(prompt, max_new=4)
        key = jax.random.PRNGKey(0)
        for _ in range(200):
            key, sub = jax.random.split(key)
            finished = cp.step(sub)
            if finished:
                break
        assert cp.metrics.nan_drops >= 1
        req = finished[0]
        assert req.rid == rid
        assert np.isfinite(np.asarray(req.gen_logp, np.float64)).all()
        rb = cp.rollout_batch([req], prompt_pad=8, max_new=4)
        assert np.isfinite(rb.gen_logp).all()


# ------------------------------------------- sharded restore on a real mesh
_SHARDED_SCRIPT = textwrap.dedent("""
    import dataclasses, json, os, sys
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.distributed.sharding import ShardingEnv, use_sharding
    from repro.models import model as M
    from repro.training.checkpoints import restore_sharded, save_checkpoint

    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    env = ShardingEnv(mesh)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    shardings = M.param_shardings(cfg, env)
    path = sys.argv[1]
    with mesh, use_sharding(env):
        save_checkpoint(path, params, {"arch": "toy-2m", "v": 9})
        restored, meta = restore_sharded(path, shardings)
    assert meta["v"] == 9
    n_sharded = 0
    for (kp, leaf), sh in zip(
            jax.tree_util.tree_flatten_with_path(restored)[0],
            jax.tree.leaves(shardings)):
        assert leaf.sharding == sh, (kp, leaf.sharding, sh)
        if len(leaf.shape) >= 2 and not sh.is_fully_replicated:
            n_sharded += 1
    orig = jax.tree.leaves(params)
    for a, b in zip(orig, jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(json.dumps({"n_devices": jax.device_count(),
                      "n_sharded_weights": n_sharded}))
""")


def test_restore_sharded_on_multidevice_mesh(tmp_path):
    """Checkpoint roundtrip + ``restore_sharded`` against the production
    mesh spec (ShardingEnv logical-axis rules) on an 8-device host
    platform: every leaf lands on its mesh sharding, weights actually
    sharded, values bit-exact. Runs in a subprocess because XLA_FLAGS
    must be set before the first jax import."""
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT, str(tmp_path / "ck")],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["n_devices"] == 8
    assert out["n_sharded_weights"] > 0
