"""Property-based tests (hypothesis) for the obs histogram.

Invariants:
1. ``quantile`` is bounded by the observed data range and monotone in q.
2. ``max`` equals the true maximum (including all-negative data).
3. ``merge`` is equivalent to observing the union of both streams.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.obs.metrics import Histogram

BOUNDS = (0.0, 0.5, 1.0, 2.0, 4.0, 8.0)
values = st.lists(st.floats(min_value=-16.0, max_value=16.0,
                            allow_nan=False), min_size=1, max_size=64)
quantiles = st.floats(min_value=0.01, max_value=1.0)


def _hist(vals):
    h = Histogram(BOUNDS)
    for v in vals:
        h.observe(v)
    return h


@settings(max_examples=80, deadline=None)
@given(values, quantiles)
def test_quantile_bounded_by_data_range(vals, q):
    h = _hist(vals)
    lo = min(min(vals), 0.0)  # first bucket lower bound is min(0, b0)
    hi = max(max(vals), BOUNDS[-1]) + 1e-9
    est = h.quantile(q)
    assert lo - 1e-9 <= est <= hi, (est, lo, hi)


@settings(max_examples=80, deadline=None)
@given(values, quantiles, quantiles)
def test_quantile_monotone(vals, q1, q2):
    h = _hist(vals)
    lo, hi = sorted((q1, q2))
    assert h.quantile(lo) <= h.quantile(hi) + 1e-9


@settings(max_examples=80, deadline=None)
@given(values)
def test_max_is_true_max(vals):
    assert _hist(vals).max == pytest.approx(max(vals))


@settings(max_examples=80, deadline=None)
@given(values, values)
def test_merge_equals_union(a, b):
    merged = _hist(a).merge(_hist(b))
    union = _hist(a + b)
    assert merged.counts == union.counts
    assert merged.total == union.total
    assert merged.sum == pytest.approx(union.sum)
    assert merged.max == pytest.approx(union.max)
    for q in (0.25, 0.5, 0.9, 0.99):
        assert merged.quantile(q) == pytest.approx(union.quantile(q))
