"""Algorithm-registry tests: built-in coverage and aliases, requires-flags
contracts, parity of Algorithm objects with the PR-2 scan engine, custom
registration end-to-end, the deprecation shims over the legacy stringly
``method`` surface, and the kl_coef wiring."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.core.algorithms import (
    A3PO,
    BUILTINS,
    Algorithm,
    LossInputs,
    available,
    get_algorithm,
    register,
    registry_table,
    resolve_algorithm,
    unregister,
)
from repro.core.objective import (
    apply_regularizers,
    common_metrics,
    masked_mean,
    policy_objective,
)
from repro.training.trainer import Trainer, TrainState

from test_training_engine import (
    PARITY_KEYS,
    make_batch,
    reference_loop_step,
)


@pytest.fixture(scope="module")
def toy():
    return dataclasses.replace(get_config("toy-2m"), dtype="float32")


@pytest.fixture(scope="module")
def rl():
    return RLConfig(group_size=4, num_minibatches=2, learning_rate=3e-4)


def rand_loss_inputs(B=4, T=10, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    logp = -jax.random.uniform(ks[0], (B, T)) * 2
    behav = logp + 0.2 * jax.random.normal(ks[1], (B, T))
    adv = jax.random.normal(ks[2], (B, T))
    mask = (jax.random.uniform(ks[3], (B, T)) > 0.2).astype(jnp.float32)
    versions = jnp.arange(B, dtype=jnp.int32)
    return logp, LossInputs(advantages=adv, mask=mask, behav_logp=behav,
                            versions=versions, current_version=B)


# ----------------------------------------------------------------- registry
def test_registry_builtins_and_aliases():
    assert set(available()) == set(BUILTINS)
    assert get_algorithm("loglinear").name == "a3po"
    assert isinstance(get_algorithm("loglinear"), A3PO)
    with pytest.raises(ValueError, match="unknown algorithm"):
        get_algorithm("nope")
    # frozen instances hash/compare by value -> stable jit-static keys
    assert hash(get_algorithm("a3po")) == hash(A3PO())
    assert get_algorithm("grpo_mu") == get_algorithm("grpo_mu")
    assert A3PO(schedule="exp") != A3PO()
    names = {r["name"] for r in registry_table()}
    assert names == set(BUILTINS)


def test_requires_flags_contract():
    """`recompute` is the only built-in that triggers the extra prox
    forward pass; `asympo` the only one that needs no behavior logps."""
    prox_users = [n for n in BUILTINS
                  if get_algorithm(n).needs_prox_forward]
    assert prox_users == ["recompute"]
    no_behav = [n for n in BUILTINS
                if not get_algorithm(n).needs_behav_logp]
    assert no_behav == ["asympo"]
    on_policy = [n for n in BUILTINS if get_algorithm(n).on_policy]
    assert on_policy == ["sync"]
    version_users = {n for n in BUILTINS
                     if get_algorithm(n).needs_versions}
    assert version_users == {"a3po", "grpo_mu"}


def test_resolve_algorithm_fallbacks():
    a = A3PO(schedule="exp")
    assert resolve_algorithm(a) is a
    assert resolve_algorithm("sync").name == "sync"
    # nested per-algorithm config in RLConfig wins over the legacy string
    assert resolve_algorithm(None, RLConfig(algo=a)) is a
    assert resolve_algorithm(None, RLConfig(method="recompute")).name \
        == "recompute"
    assert resolve_algorithm(None, None).name == "a3po"
    with pytest.raises(TypeError):
        resolve_algorithm(42)


# ------------------------------------------------- scan-engine parity pins
@pytest.mark.parametrize("name", ["sync", "recompute", "a3po"])
def test_algorithm_objects_pin_scan_engine(toy, rl, name):
    """Algorithm *objects* reproduce the PR-2 scan-engine outputs that the
    seed loop oracle pins (same oracle as the method-string parity test)."""
    batch = make_batch(False, seed=1)
    legacy = {"a3po": "loglinear"}.get(name, name)
    trainer = Trainer(toy, rl, get_algorithm(name))
    s_scan = trainer.init_state(jax.random.PRNGKey(3))
    s_ref = trainer.init_state(jax.random.PRNGKey(3))
    s_scan = TrainState(s_scan.params, s_scan.opt, jnp.asarray(2, jnp.int32))
    s_ref = TrainState(s_ref.params, s_ref.opt, jnp.asarray(2, jnp.int32))

    s_ref, m_ref = reference_loop_step(toy, rl, legacy, s_ref, batch)
    s_scan, m_scan = trainer.step(s_scan, batch)
    for k in PARITY_KEYS:
        np.testing.assert_allclose(m_scan[k], m_ref[k], rtol=2e-4,
                                   atol=1e-5, err_msg=k)
    for a, b in zip(jax.tree.leaves(s_scan.params),
                    jax.tree.leaves(s_ref.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-6)


def test_flags_gate_scan_operands(toy, rl):
    """Tensors an algorithm does not require never enter the compiled
    minibatch scan: asympo trains through NaN behavior logps."""
    batch = make_batch(False, seed=2)
    poisoned = dataclasses.replace(
        batch, behav_logp=jnp.full_like(batch.behav_logp, jnp.nan))
    tr = Trainer(toy, rl, "asympo")
    state = tr.init_state(jax.random.PRNGKey(0))
    state, m = tr.step(state, poisoned)
    assert np.isfinite(m["loss"])
    assert m["host_syncs"] == 1.0  # and no prox forward pass
    # a behav-requiring algorithm does propagate the NaNs (sanity check
    # that the poisoning is real)
    tr2 = Trainer(toy, rl, "grpo_mu")
    s2 = tr2.init_state(jax.random.PRNGKey(0))
    _, m2 = tr2.step(s2, poisoned)
    assert not np.isfinite(m2["loss"])


# --------------------------------------------------- custom registration
def test_custom_algorithm_end_to_end(toy, rl):
    """A one-class plugin registers and trains through the full engine."""

    @register("test_reinforce")
    @dataclasses.dataclass(frozen=True)
    class TestReinforce(Algorithm):
        adv_cap: float = 5.0
        needs_behav_logp = False
        needs_versions = False

        def loss(self, logp, batch, cfg):
            logp = logp.astype(jnp.float32)
            adv = jnp.clip(batch.advantages, -self.adv_cap, self.adv_cap)
            loss = -masked_mean(logp * adv, batch.mask)
            ratio = jnp.ones_like(logp)
            metrics = common_metrics(ratio, ratio, jnp.zeros_like(logp),
                                     batch.mask, batch.entropy)
            return apply_regularizers(loss, metrics, logp, logp,
                                      batch.mask, cfg, batch.entropy)

    try:
        assert "test_reinforce" in available()
        tr = Trainer(toy, rl, "test_reinforce")
        assert tr.algo == TestReinforce()
        state = tr.init_state(jax.random.PRNGKey(0))
        state, m = tr.step(state, make_batch(False, seed=3))
        assert np.isfinite(m["loss"]) and int(state.version) == 1
    finally:
        unregister("test_reinforce")
    assert "test_reinforce" not in available()


def test_registry_tolerates_plugin_edge_cases():
    """Docstring-less plugins don't break --algo list, and unregistering
    by alias removes the whole entry (canonical + aliases) cleanly."""

    # a plain (non-dataclass) subclass carries __doc__ = None — the
    # sparsest plugin registration must not break the table
    @register("test_nodoc", aliases=("test_nodoc_alias",))
    class NoDoc(Algorithm):
        def loss(self, logp, batch, cfg):  # pragma: no cover - unused
            raise NotImplementedError

    try:
        row = [r for r in registry_table() if r["name"] == "test_nodoc"][0]
        assert row["doc"] == ""
        assert row["aliases"] == ["test_nodoc_alias"]
    finally:
        unregister("test_nodoc_alias")  # by alias, not canonical name
    assert "test_nodoc" not in available()
    with pytest.raises(ValueError) as e:
        get_algorithm("test_nodoc_alias")
    # the advertised alias list no longer contains the stale alias
    assert "test_nodoc_alias" not in str(e.value).split("aliases:")[1]

    # a colliding registration must leave the registry untouched — no
    # half-inserted canonical name pointing at an unstamped class
    before = available()
    with pytest.raises(ValueError, match="already registered"):
        @register("test_orphan", aliases=("sync",))
        @dataclasses.dataclass(frozen=True)
        class Colliding(Algorithm):
            def loss(self, logp, batch, cfg):  # pragma: no cover
                raise NotImplementedError
    assert available() == before
    with pytest.raises(ValueError):
        get_algorithm("test_orphan")


# ------------------------------------------------------ deprecation shims
def test_trainer_method_kwarg_shim(toy, rl):
    with pytest.warns(DeprecationWarning, match="method"):
        tr = Trainer(toy, rl, method="loglinear")
    assert tr.algo.name == "a3po"
    assert tr.method == "a3po"  # legacy attribute survives


def test_policy_objective_string_shim():
    logp, b = rand_loss_inputs()
    cfg = RLConfig()
    kw = dict(versions=b.versions, current_version=b.current_version)
    with pytest.warns(DeprecationWarning):
        l1, m1 = policy_objective("loglinear", logp, b.behav_logp,
                                  b.advantages, b.mask, cfg, **kw)
    with pytest.warns(DeprecationWarning):
        l2, _ = policy_objective(method="loglinear", logp=logp,
                                 behav_logp=b.behav_logp,
                                 advantages=b.advantages, mask=b.mask,
                                 cfg=cfg, **kw)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # Algorithm objects must not warn
        l3, _ = policy_objective(get_algorithm("a3po"), logp, b.behav_logp,
                                 b.advantages, b.mask, cfg, **kw)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-7)
    np.testing.assert_allclose(float(l1), float(l3), rtol=1e-7)
    assert "kl" in m1


def test_losses_compat_layer():
    from repro.core.losses import policy_loss
    from repro.core import losses as L
    for sym in ("Algorithm", "LossInputs", "get_algorithm",
                "resolve_algorithm", "coupled_ppo_loss",
                "decoupled_ppo_loss", "policy_objective"):
        assert hasattr(L, sym), sym
    logp, b = rand_loss_inputs()
    with pytest.warns(DeprecationWarning):
        loss, m = policy_loss("sync", logp, b.behav_logp, b.advantages,
                              b.mask, RLConfig())
    assert np.isfinite(float(loss))


# ------------------------------------------------------------ kl_coef wire
def test_kl_coef_wired_into_every_builtin(toy):
    logp, b = rand_loss_inputs(seed=5)
    for name in BUILTINS:
        algo = get_algorithm(name)
        bb = b._replace(prox_logp=(b.behav_logp
                                   if algo.needs_prox_forward else None))
        l0, m0 = algo.loss(logp, bb, RLConfig(kl_coef=0.0))
        l1, m1 = algo.loss(logp, bb, RLConfig(kl_coef=0.7))
        assert "kl" in m0 and np.isfinite(float(m0["kl"])), name
        np.testing.assert_allclose(float(l1), float(l0)
                                   + 0.7 * float(m0["kl"]),
                                   rtol=1e-5, atol=1e-7, err_msg=name)


def test_kl_metric_through_trainer(toy, rl):
    tr = Trainer(toy, dataclasses.replace(rl, kl_coef=0.1), "a3po")
    state = tr.init_state(jax.random.PRNGKey(0))
    _, m = tr.step(state, make_batch(False, seed=4))
    assert np.isfinite(m["kl"])


def test_kl_penalty_pulls_toward_anchor():
    """With zero advantages the sync loss is flat; the KL penalty alone
    must push logp toward the behavior anchor (k1 gradient = +1/denom)."""
    cfg = RLConfig(kl_coef=1.0)
    behav = jnp.full((1, 4), -1.0)
    mask = jnp.ones((1, 4))
    algo = get_algorithm("sync")

    def f(lp):
        return algo.loss(lp, LossInputs(
            advantages=jnp.zeros((1, 4)), mask=mask, behav_logp=behav),
            cfg)[0]

    g = jax.grad(f)(jnp.full((1, 4), -0.5))
    assert bool(jnp.all(g > 0))  # descending lowers logp toward behav


# ------------------------------------------------- beyond-paper built-ins
def test_asympo_is_behavior_free():
    logp, b = rand_loss_inputs(seed=6)
    algo = get_algorithm("asympo")
    # no behavior logps, no versions — the minimal LossInputs suffices
    loss, m = algo.loss(logp, LossInputs(advantages=b.advantages,
                                         mask=b.mask), RLConfig())
    assert np.isfinite(float(loss))
    np.testing.assert_allclose(float(m["iw_mean"]), 1.0, atol=1e-6)
    # asymmetric scales: negative advantages weigh neg_scale/pos_scale
    # harder in the gradient
    adv = jnp.ones((1, 1))
    mask = jnp.ones((1, 1))

    def g_of(a, adv_sign):
        return jax.grad(lambda lp: a.loss(lp, LossInputs(
            advantages=adv_sign * adv, mask=mask), RLConfig())[0]
        )(jnp.full((1, 1), -1.0))

    a = get_algorithm("asympo", pos_scale=1.0, neg_scale=2.0)
    g_pos = g_of(a, +1.0)
    g_neg = g_of(a, -1.0)
    np.testing.assert_allclose(np.asarray(g_neg), -2.0 * np.asarray(g_pos),
                               rtol=1e-6)


def test_grpo_mu_staleness_gated_truncation():
    cfg = RLConfig(clip_eps=0.2)
    algo = get_algorithm("grpo_mu", mu=0.5)
    mask = jnp.ones((1, 1))
    adv = jnp.ones((1, 1))
    behav = jnp.full((1, 1), -0.15)
    logp0 = jnp.zeros((1, 1))  # ratio ~ 1.16, inside the fresh cap 1.2

    def loss_at(d):
        return lambda lp: algo.loss(lp, LossInputs(
            advantages=adv, mask=mask, behav_logp=behav,
            versions=jnp.array([5 - d]), current_version=5), cfg)[0]

    # fresh (d=0): cap = 1 + eps = 1.2 — full PPO range, live gradient
    g_fresh = jax.grad(loss_at(0))(logp0)
    assert abs(float(g_fresh[0, 0])) > 1e-4
    # stale (d=4): cap = 1 + 0.2 * 0.5^4 = 1.0125 < ratio — truncated,
    # the stale sample cannot be up-weighted and carries no gradient
    g_stale = jax.grad(loss_at(4))(logp0)
    np.testing.assert_allclose(np.asarray(g_stale), 0.0, atol=1e-8)
    _, m = algo.loss(logp0, LossInputs(
        advantages=adv, mask=mask, behav_logp=behav,
        versions=jnp.array([1]), current_version=5), cfg)
    np.testing.assert_allclose(float(m["iw_max"]),
                               1.0 + 0.2 * 0.5 ** 4, rtol=1e-6)


def test_nested_algo_config_schedule_override(toy, rl):
    """A3PO(schedule=...) overrides cfg.alpha_schedule per instance."""
    logp, b = rand_loss_inputs(seed=7)
    cfg = RLConfig(alpha_schedule="inverse", alpha_const=0.25)
    l_inv, _ = get_algorithm("a3po").loss(logp, b, cfg)
    l_const, _ = A3PO(schedule="const").loss(logp, b, cfg)
    l_const_direct, _ = get_algorithm("a3po").loss(
        logp, b, dataclasses.replace(cfg, alpha_schedule="const"))
    assert float(l_inv) != float(l_const)
    np.testing.assert_allclose(float(l_const), float(l_const_direct),
                               rtol=1e-7)
    # and it threads through RLConfig.algo into the Trainer
    rl2 = dataclasses.replace(rl, algo=A3PO(schedule="const"))
    assert Trainer(toy, rl2).algo == A3PO(schedule="const")
