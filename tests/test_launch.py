"""Launch-layer tests: input specs, shardings, lowering on a local mesh,
and the trip-count-aware HLO cost parser."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import InputShape, RLConfig, SHAPES
from repro.configs.registry import get_config
from repro.distributed.hlo_cost import analyze
from repro.distributed.sharding import ShardingEnv, use_sharding
from repro.launch import steps
from repro.launch.mesh import make_local_mesh
from repro.models import model as M


def test_hlo_cost_plain_matmul():
    m, n, k = 32, 48, 64
    f = jax.jit(lambda a, b: a @ b)
    txt = f.lower(jax.ShapeDtypeStruct((m, k), jnp.float32),
                  jax.ShapeDtypeStruct((k, n), jnp.float32)
                  ).compile().as_text()
    c = analyze(txt)
    assert c.flops == 2 * m * n * k


def test_hlo_cost_scan_trip_counts():
    m, k, L = 32, 64, 7

    def g(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    txt = jax.jit(g).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((L, k, k), jnp.float32)).compile().as_text()
    c = analyze(txt)
    assert c.flops == L * 2 * m * k * k
    assert list(c.while_trips.values()) == [L]


def test_hlo_cost_nested_scan():
    m, k = 16, 32

    def h(x, ws):
        def outer(carry, wset):
            return jax.lax.scan(lambda c, w: (c @ w, None), carry,
                                wset)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    txt = jax.jit(h).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((3, 5, k, k), jnp.float32)).compile().as_text()
    c = analyze(txt)
    assert c.flops == 15 * 2 * m * k * k


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "mamba2-370m",
                                  "deepseek-v2-lite-16b"])
def test_reduced_train_step_lowers_on_local_mesh(arch):
    """The dryrun program (shardings included) compiles on the real local
    mesh for reduced configs — same code path as the 512-device dry-run."""
    cfg = dataclasses.replace(get_config(arch + "-reduced"), dtype="float32")
    shape = InputShape("tiny_train", 32, 4, "train")
    rl = RLConfig()
    mesh = make_local_mesh()
    env = ShardingEnv(mesh)
    specs = steps.input_specs(cfg, shape)
    step = steps.make_step(cfg, shape, rl, "loglinear")
    params_abs = M.abstract_params(cfg)
    param_sh = M.param_shardings(cfg, env)
    batch_sh = steps.batch_shardings(cfg, shape, env, specs)
    with mesh, use_sharding(env):
        opt_abs = steps.abstract_opt_state(params_abs)
        opt_sh = steps.opt_shardings(param_sh, env)
        compiled = jax.jit(
            step, in_shardings=(param_sh, opt_sh, batch_sh)).lower(
            params_abs, opt_abs, specs).compile()
    assert compiled.cost_analysis() is not None


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "musicgen-large"])
def test_reduced_decode_step_lowers(arch):
    cfg = dataclasses.replace(get_config(arch + "-reduced"), dtype="float32")
    shape = InputShape("tiny_decode", 64, 4, "decode")
    rl = RLConfig()
    mesh = make_local_mesh()
    env = ShardingEnv(mesh)
    specs = steps.input_specs(cfg, shape)
    step = steps.make_step(cfg, shape, rl)
    params_abs = M.abstract_params(cfg)
    param_sh = M.param_shardings(cfg, env)
    batch_sh = steps.batch_shardings(cfg, shape, env, specs)
    with mesh, use_sharding(env):
        compiled = jax.jit(step, in_shardings=(param_sh, batch_sh)).lower(
            params_abs, specs).compile()
    assert compiled is not None


def test_train_step_microbatch_equivalence():
    """Gradient accumulation (nm=4) == single batch update (nm=1)."""
    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    rl = RLConfig(learning_rate=1e-3)
    B, S = 8, 12
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    from repro.training.optimizer import adam_init
    batch = {
        "tokens": jax.random.randint(key, (B, S), 4, cfg.vocab_size),
        "behav_logp": -jnp.ones((B, S - 1)),
        "advantages": jax.random.normal(jax.random.PRNGKey(1), (B, S - 1)),
        "mask": jnp.ones((B, S - 1)),
        "versions": jnp.zeros((B,), jnp.int32),
    }
    outs = {}
    for nm in (1, 4):
        step = steps.make_train_step(cfg, rl, "loglinear",
                                     num_microbatches=nm)
        p2, _, loss, _, gnorm = jax.jit(step)(params, adam_init(params),
                                              batch)
        outs[nm] = (p2, float(loss))
    # losses match exactly; params match to accumulation tolerance.
    # NOTE: loglinear prox depends on the *microbatch's own* live logp, so
    # grads differ only via f32 accumulation order.
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=1e-5)
    a = jax.tree.leaves(outs[1][0])
    b = jax.tree.leaves(outs[4][0])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=5e-3, atol=5e-5)


def test_input_specs_no_allocation():
    """Specs are abstract — building them must not allocate device arrays."""
    cfg = get_config("command-r-plus-104b")
    specs = steps.input_specs(cfg, SHAPES["decode_32k"])
    leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    assert all(isinstance(leaf, jax.ShapeDtypeStruct) for leaf in leaves)
    total = sum(np.prod(leaf.shape) * leaf.dtype.itemsize
                for leaf in leaves)
    assert total > 2**40  # the full-scale cache would be >1TiB if real


def test_chunked_prefill_equivalence():
    """Batch-chunked prefill (nm=4) == unchunked (logits + cache)."""
    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    shape = InputShape("t", 16, 8, "prefill")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 4,
                                          cfg.vocab_size)}
    l1, c1 = steps.make_prefill_step(cfg, shape, 1)(params, batch)
    l4, c4 = steps.make_prefill_step(cfg, shape, 4)(params, batch)
    np.testing.assert_allclose(l1, l4, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
