"""Per-kernel correctness sweeps: Pallas (interpret=True) vs pure-jnp
oracle across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.a3po_loss.kernel import a3po_loss_pallas
from repro.kernels.a3po_loss.ref import a3po_loss_ref
from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref
from repro.kernels.logprob.kernel import token_logprob_entropy_pallas
from repro.kernels.logprob.ref import token_logprob_entropy_ref
from repro.kernels.ssd.kernel import ssd_intra_chunk_pallas
from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.ssd.ref import ssd_sequential_ref


# ------------------------------------------------------------------ logprob
@pytest.mark.parametrize("T,d,V", [
    (16, 32, 50), (300, 130, 1000), (64, 512, 513), (7, 48, 22),
    (128, 64, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_logprob_kernel_vs_ref(T, d, V, dtype):
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (T, d), dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (d, V), jnp.float32)
         * 0.05).astype(dtype)
    t = jax.random.randint(jax.random.PRNGKey(2), (T,), 0, V)
    lp_k, en_k = token_logprob_entropy_pallas(h, w, t, bt=64, bv=128, bd=64,
                                              interpret=True)
    lp_r, en_r = token_logprob_entropy_ref(h, w, t)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(lp_k, lp_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(en_k, en_r, rtol=tol, atol=tol)


def test_logprob_is_valid_distribution():
    """exp(logp) must be <= 1 and entropy >= 0."""
    key = jax.random.PRNGKey(3)
    h = jax.random.normal(key, (32, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (16, 97), jnp.float32)
    t = jax.random.randint(jax.random.PRNGKey(5), (32,), 0, 97)
    lp, en = token_logprob_entropy_pallas(h, w, t, interpret=True)
    assert np.all(np.asarray(lp) <= 1e-5)
    assert np.all(np.asarray(en) >= -1e-5)


# --------------------------------------------------------------- flash attn
@pytest.mark.parametrize("B,H,KV,S,hd,window", [
    (2, 4, 2, 64, 32, None),   # GQA
    (1, 4, 4, 128, 16, None),  # MHA
    (2, 2, 1, 64, 32, None),   # MQA
    (2, 2, 1, 64, 32, 32),     # sliding window
    (1, 8, 2, 96, 64, None),   # non-power-of-two seq (96 = 3*32)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(B, H, KV, S, hd, window, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, hd), dtype)
    o_k = flash_attention_pallas(q, k, v, bq=32, bk=32, window=window,
                                 interpret=True)
    o_r = flash_attention_ref(q, k, v, window=window)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------- ssd
@pytest.mark.parametrize("B,S,nh,hd,ds,cs", [
    (2, 64, 4, 16, 8, 16), (1, 48, 2, 8, 4, 16), (2, 32, 1, 4, 4, 32),
    (1, 128, 2, 32, 16, 32)])
def test_ssd_kernel_vs_sequential(B, S, nh, hd, ds, cs):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3),
                                           (B, S, nh)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, nh))
    b = jax.random.normal(jax.random.PRNGKey(4), (B, S, ds)) * 0.3
    c = jax.random.normal(jax.random.PRNGKey(5), (B, S, ds)) * 0.3
    y_k, f_k = ssd_scan(x, dt, a_log, b, c, chunk=cs, interpret=True)
    y_r, f_r = ssd_sequential_ref(x, dt, a_log, b, c)
    np.testing.assert_allclose(y_k, y_r, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(f_k, f_r, rtol=1e-3, atol=1e-3)


def test_ssd_initial_state_continuity():
    """Splitting a sequence at a chunk boundary and carrying the state must
    equal one contiguous scan (the decode-handoff invariant)."""
    key = jax.random.PRNGKey(0)
    B, S, nh, hd, ds = 1, 64, 2, 8, 4
    x = jax.random.normal(key, (B, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (B, S, nh)))
    a_log = jnp.zeros((nh,))
    b = jax.random.normal(jax.random.PRNGKey(2), (B, S, ds)) * 0.3
    c = jax.random.normal(jax.random.PRNGKey(3), (B, S, ds)) * 0.3
    y_full, f_full = ssd_sequential_ref(x, dt, a_log, b, c)
    y1, f1 = ssd_sequential_ref(x[:, :32], dt[:, :32], a_log, b[:, :32],
                                c[:, :32])
    y2, f2 = ssd_sequential_ref(x[:, 32:], dt[:, 32:], a_log, b[:, 32:],
                                c[:, 32:], initial_state=f1)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(f2, f_full, rtol=1e-4, atol=1e-4)


def test_ssd_intra_chunk_outputs():
    """Kernel intra-chunk output matches a one-chunk sequential scan."""
    key = jax.random.PRNGKey(7)
    B, S, nh, hd, ds = 1, 16, 2, 8, 4
    x = jax.random.normal(key, (B, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(8),
                                           (B, S, nh)))
    a_log = jnp.log(jnp.array([1.0, 2.0]))
    b = jax.random.normal(jax.random.PRNGKey(9), (B, S, ds)) * 0.3
    c = jax.random.normal(jax.random.PRNGKey(10), (B, S, ds)) * 0.3
    la = dt * (-jnp.exp(a_log))
    xdt = x * dt[..., None]
    y, s_local, cdec = ssd_intra_chunk_pallas(xdt, la, b, c, chunk=16,
                                              interpret=True)
    y_r, f_r = ssd_sequential_ref(x, dt, a_log, b, c)
    np.testing.assert_allclose(y[:, :, 0], y_r[:, :, 0], rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(s_local[:, 0], f_r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- a3po loss
@pytest.mark.parametrize("T", [64, 1000, 4096])
def test_a3po_loss_kernel_vs_ref(T):
    key = jax.random.PRNGKey(0)
    lp = -jax.random.uniform(key, (T,)) * 3
    bl = -jax.random.uniform(jax.random.PRNGKey(6), (T,)) * 3
    al = jax.random.uniform(jax.random.PRNGKey(7), (T,))
    adv = jax.random.normal(jax.random.PRNGKey(8), (T,))
    mask = (jax.random.uniform(jax.random.PRNGKey(9), (T,)) > 0.3
            ).astype(jnp.float32)
    l_k, c_k, iw_k, r_k = a3po_loss_pallas(lp, bl, al, adv, mask, bt=128,
                                           interpret=True)
    l_r, c_r, iw_r, r_r = a3po_loss_ref(lp, bl, al, adv, mask, clip_eps=0.2,
                                        iw_cap=5.0)
    np.testing.assert_allclose(l_k, l_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(c_k, c_r)
    np.testing.assert_allclose(iw_k, iw_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(r_k, r_r, rtol=2e-5, atol=2e-5)


def test_a3po_fused_matches_modular_loss():
    """The fused kernel must agree with core.losses.decoupled_ppo_loss."""
    from repro.configs.base import RLConfig
    from repro.core.a3po import compute_prox_logp_approximation
    from repro.core.losses import decoupled_ppo_loss

    key = jax.random.PRNGKey(0)
    B, T = 4, 32
    cfg = RLConfig()
    logp = -jax.random.uniform(key, (B, T)) * 3
    behav = -jax.random.uniform(jax.random.PRNGKey(1), (B, T)) * 3
    adv = jax.random.normal(jax.random.PRNGKey(2), (B, T))
    mask = jnp.ones((B, T))
    versions = jnp.array([0, 1, 2, 3])
    prox = compute_prox_logp_approximation(behav, logp, versions, 3, cfg)
    l_mod, m = decoupled_ppo_loss(logp, behav, prox, adv, mask, cfg)

    from repro.core.a3po import alpha_from_staleness, staleness
    alpha = jnp.broadcast_to(
        alpha_from_staleness(staleness(versions, 3), cfg)[:, None], (B, T))
    l_tok, clip_tok, iw_tok, _ = a3po_loss_pallas(
        logp.reshape(-1), behav.reshape(-1), alpha.reshape(-1),
        adv.reshape(-1), mask.reshape(-1), clip_eps=cfg.clip_eps,
        iw_cap=cfg.behav_weight_cap, interpret=True)
    np.testing.assert_allclose(l_tok.sum() / mask.sum(), l_mod,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(clip_tok.sum(), m["clipped_tokens"])
    np.testing.assert_allclose(iw_tok.max(), m["iw_max"], rtol=1e-6)


# --------------------------------------------------------------- decode attn
@pytest.mark.parametrize("B,H,KV,L,hd,bk", [
    (2, 4, 2, 64, 32, 32), (1, 8, 1, 128, 16, 64), (3, 4, 4, 96, 32, 32)])
def test_decode_attention_kernel_vs_ref(B, H, KV, L, hd, bk):
    from repro.kernels.decode_attn.kernel import decode_attention_pallas
    from repro.models.attention import decode_attention as ref
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, hd), jnp.float32)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, L, KV, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, L, KV, hd))
    lengths = jax.random.randint(jax.random.PRNGKey(3), (B,), 1, L + 1)
    o_k = decode_attention_pallas(q, kc, vc, lengths, bk=bk, interpret=True)
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    o_r = ref(q, kc, vc, valid)
    np.testing.assert_allclose(o_k, o_r, rtol=2e-4, atol=2e-4)


def _paged_pool(rng_seed, S, KV, n_blocks, bs, mb, hd):
    """Random pool + disjoint per-sequence block tables + lengths."""
    rng = np.random.default_rng(rng_seed)
    pool_k = jax.random.normal(jax.random.PRNGKey(1),
                               (n_blocks, bs, KV, hd), jnp.float32)
    pool_v = jax.random.normal(jax.random.PRNGKey(2),
                               (n_blocks, bs, KV, hd), jnp.float32)
    tables = rng.permutation(n_blocks)[: S * mb].reshape(S, mb)
    lengths = rng.integers(1, mb * bs + 1, size=S)
    # entries past the mapped region are -1, as in the serving engine
    for s in range(S):
        tables[s, -(-int(lengths[s]) // bs):] = -1
    return (pool_k, pool_v, jnp.asarray(tables, jnp.int32),
            jnp.asarray(lengths, jnp.int32))


@pytest.mark.parametrize("S,H,KV,n_blocks,bs,mb,hd", [
    (2, 4, 2, 16, 8, 4, 32),    # GQA
    (3, 4, 4, 32, 16, 2, 16),   # MHA
    (1, 8, 1, 8, 4, 6, 32),     # MQA
])
def test_paged_decode_attention_kernel_vs_ref(S, H, KV, n_blocks, bs, mb,
                                              hd):
    """Paged Pallas kernel (block-table gather inside the kernel) matches
    the XLA-gather oracle over shuffled, partially-mapped block tables."""
    from repro.kernels.decode_attn.paged_kernel import (
        paged_decode_attention_pallas,
    )
    from repro.kernels.decode_attn.ref import paged_decode_attention_ref
    pool_k, pool_v, tables, lengths = _paged_pool(0, S, KV, n_blocks, bs,
                                                  mb, hd)
    q = jax.random.normal(jax.random.PRNGKey(0), (S, H, hd), jnp.float32)
    o_k = paged_decode_attention_pallas(q, pool_k, pool_v, tables, lengths,
                                        interpret=True)
    o_r = paged_decode_attention_ref(q, pool_k, pool_v, tables, lengths)
    np.testing.assert_allclose(o_k, o_r, rtol=2e-4, atol=2e-4)


def test_paged_decode_attention_op_dispatch():
    """The op's non-TPU path equals both oracles (shared kernel coverage
    between the fused horizon and the single-step fallback)."""
    from repro.kernels.decode_attn.ops import paged_decode_attention_op
    from repro.kernels.decode_attn.ref import paged_decode_attention_ref
    pool_k, pool_v, tables, lengths = _paged_pool(1, 2, 2, 16, 8, 3, 16)
    q = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 16), jnp.float32)
    o_op = paged_decode_attention_op(q, pool_k, pool_v, tables, lengths)
    o_ref = paged_decode_attention_ref(q, pool_k, pool_v, tables, lengths)
    np.testing.assert_allclose(o_op, o_ref, rtol=1e-6, atol=1e-6)
    o_int = paged_decode_attention_op(q, pool_k, pool_v, tables, lengths,
                                      interpret=True)
    np.testing.assert_allclose(o_int, o_ref, rtol=2e-4, atol=2e-4)


# -------------------------------------------------------------- prefill attn
def _prefill_chunk(rng_seed, C, S, KV, n_blocks, bs, mb, hd, *,
                   pad_rows=0):
    """A packed prefill chunk over ``_paged_pool``: rows round-robin the
    segments, each taking that segment's next positions; trailing rows
    are padding (seg -1)."""
    pool_k, pool_v, tables, lengths = _paged_pool(rng_seed, S, KV, n_blocks,
                                                  bs, mb, hd)
    rng = np.random.default_rng(rng_seed + 100)
    seg = np.full((C,), -1, np.int32)
    pos = np.zeros((C,), np.int32)
    # each segment contributes a contiguous run of its last positions
    # (kv_lens[s] keys resident -> rows at positions < lengths[s])
    cursor = {s: max(int(lengths[s]) - rng.integers(1, 4), 0)
              for s in range(S)}
    for i in range(C - pad_rows):
        s = i % S
        if cursor[s] >= int(lengths[s]):
            continue  # segment exhausted; leave row as padding
        seg[i] = s
        pos[i] = cursor[s]
        cursor[s] += 1
    q = jax.random.normal(jax.random.PRNGKey(rng_seed + 7), (C, 8, hd),
                          jnp.float32)
    return (q, pool_k, pool_v, tables, jnp.asarray(seg), jnp.asarray(pos),
            lengths)


@pytest.mark.parametrize("C,S,KV,n_blocks,bs,mb,hd", [
    (8, 2, 2, 16, 8, 4, 32),    # GQA, packed 2 segments
    (16, 3, 4, 32, 16, 2, 16),  # MHA, 3-way packing
    (4, 1, 1, 8, 4, 6, 32),     # MQA, single segment
])
def test_paged_prefill_attention_kernel_vs_ref(C, S, KV, n_blocks, bs, mb,
                                               hd):
    """Chunked prefill Pallas kernel (block-table walk + per-row causal
    segment mask) matches the per-row decode-replay oracle, padding rows
    emit zeros."""
    from repro.kernels.prefill_attn.kernel import (
        paged_prefill_attention_pallas,
    )
    from repro.kernels.prefill_attn.ref import paged_prefill_attention_ref
    q, pool_k, pool_v, tables, seg, pos, lengths = _prefill_chunk(
        0, C, S, KV, n_blocks, bs, mb, hd, pad_rows=1)
    o_k = paged_prefill_attention_pallas(q, pool_k, pool_v, tables, seg,
                                         pos, lengths, interpret=True)
    o_r = paged_prefill_attention_ref(q, pool_k, pool_v, tables, seg, pos)
    np.testing.assert_allclose(o_k, o_r, rtol=2e-4, atol=2e-4)
    pad = np.asarray(seg) < 0
    assert pad.any()
    assert np.all(np.asarray(o_k)[pad] == 0.0)


def test_paged_prefill_attention_matches_decode_per_row():
    """Each chunk row must equal a single decode query at its position —
    the invariant that makes the chunk lane a drop-in for per-token
    suffix replay."""
    from repro.kernels.decode_attn.ref import paged_decode_attention_ref
    from repro.kernels.prefill_attn.ref import paged_prefill_attention_ref
    q, pool_k, pool_v, tables, seg, pos, _ = _prefill_chunk(
        2, 8, 2, 2, 16, 8, 4, 32)
    o = paged_prefill_attention_ref(q, pool_k, pool_v, tables, seg, pos)
    for i in range(8):
        s = int(seg[i])
        if s < 0:
            continue
        o_dec = paged_decode_attention_ref(
            q[i: i + 1], pool_k, pool_v, tables[s: s + 1],
            pos[i: i + 1] + 1)
        np.testing.assert_array_equal(np.asarray(o[i]),
                                      np.asarray(o_dec[0]))


def test_paged_prefill_attention_op_dispatch():
    """Op non-TPU path equals the oracle; interpret path within kernel
    tolerance."""
    from repro.kernels.prefill_attn.ops import paged_prefill_attention_op
    from repro.kernels.prefill_attn.ref import paged_prefill_attention_ref
    q, pool_k, pool_v, tables, seg, pos, lengths = _prefill_chunk(
        1, 8, 2, 2, 16, 8, 3, 16)
    o_op = paged_prefill_attention_op(q, pool_k, pool_v, tables, seg, pos,
                                      lengths)
    o_ref = paged_prefill_attention_ref(q, pool_k, pool_v, tables, seg, pos)
    np.testing.assert_allclose(o_op, o_ref, rtol=1e-6, atol=1e-6)
    o_int = paged_prefill_attention_op(q, pool_k, pool_v, tables, seg, pos,
                                       lengths, interpret=True)
    np.testing.assert_allclose(o_int, o_ref, rtol=2e-4, atol=2e-4)
