"""Per-architecture smoke tests: REDUCED variant of each assigned family
(2 layers, d_model<=512, <=4 experts) runs one forward + one train step on
CPU with correct shapes and no NaNs; decode consistency vs the full pass.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RLConfig
from repro.configs.registry import ASSIGNED, get_config
from repro.launch import steps
from repro.models import model as M
from repro.training.optimizer import adam_init

ARCHS = sorted(ASSIGNED)


def _reduced(name):
    return dataclasses.replace(get_config(name + "-reduced"),
                               dtype="float32")


def _inputs(cfg, B=2, S=16, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    toks = jax.random.randint(key, (B, S), 4, cfg.vocab_size)
    embeds = None
    if cfg.frontend:
        embeds = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return toks, embeds


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_limits(arch):
    cfg = _reduced(arch)
    assert cfg.num_layers <= 6
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = _reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks, embeds = _inputs(cfg)
    logits, aux = M.forward_logits(params, cfg, toks, embeds=embeds)
    B, S = toks.shape
    F = cfg.frontend_tokens if cfg.frontend else 0
    assert logits.shape == (B, S + F, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs(arch):
    """One full RL train step (fwd + bwd + adam) on the reduced config."""
    cfg = _reduced(arch)
    rl = RLConfig(learning_rate=1e-4)
    step = steps.make_train_step(cfg, rl, "loglinear", num_microbatches=1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adam_init(params)
    B, S = 2, 16
    toks, embeds = _inputs(cfg, B, S)
    batch = {
        "tokens": toks,
        "behav_logp": -jnp.ones((B, S - 1)) * 2,
        "advantages": jax.random.normal(jax.random.PRNGKey(1), (B, S - 1)),
        "mask": jnp.ones((B, S - 1)),
        "versions": jnp.array([1, 2], jnp.int32),
    }
    if embeds is not None:
        batch["embeds"] = embeds
    params2, opt2, loss, entropy, gnorm = jax.jit(
        step)(params, opt, batch)
    assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))
    assert float(entropy) >= 0
    # params actually changed
    diff = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     params, params2))
    assert diff > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = _reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    toks, embeds = _inputs(cfg, B, S, jax.random.PRNGKey(1))
    logits_full, _ = M.forward_logits(params, cfg, toks, embeds=embeds)
    F = cfg.frontend_tokens if cfg.frontend else 0
    _, cache = M.prefill(params, cfg, toks[:, : S - 1], embeds=embeds,
                         max_len=F + S + 4)
    logits_dec, cache2 = M.decode_step(params, cfg, cache, toks[:, S - 1])
    ref = logits_full[:, -1]
    err = float(jnp.abs(ref - logits_dec).max()
                / (jnp.abs(ref).max() + 1e-9))
    assert err < 2e-3, f"{arch}: rel err {err}"
    assert int(cache2["lengths"][0]) == int(cache["lengths"][0]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_shapes(arch):
    from repro.configs.base import SHAPES
    cfg = get_config(arch)
    for shape in SHAPES.values():
        specs = steps.input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "decode":
            assert "cache" in specs
            leaves = jax.tree.leaves(
                specs["cache"],
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            assert all(isinstance(leaf, jax.ShapeDtypeStruct)
                       for leaf in leaves)


def test_sliding_window_policy():
    """long_500k: SSM/hybrid/MLA keep full state; dense archs window."""
    from repro.configs.base import SHAPES
    long = SHAPES["long_500k"]
    assert steps.decode_window(get_config("mamba2-370m"), long) is None
    assert steps.decode_window(get_config("zamba2-1.2b"), long) is None
    assert steps.decode_window(get_config("deepseek-v2-lite-16b"),
                               long) is None
    assert steps.decode_window(get_config("codeqwen1.5-7b"), long) == 8192
    assert steps.decode_window(get_config("codeqwen1.5-7b"),
                               SHAPES["decode_32k"]) is None


def test_param_counts_match_analytic():
    """init param count == ModelConfig.num_params() for every arch."""
    from repro.models.params import count_params
    for arch in ARCHS:
        cfg = get_config(arch)
        spec_count = count_params(M.model_spec(cfg))
        analytic = cfg.num_params()
        assert spec_count == analytic, (arch, spec_count, analytic)
