import dataclasses

import jax
import pytest

# NOTE: no XLA_FLAGS here — tests run on the real single CPU device.
# Only launch/dryrun.py forces the 512-device placeholder platform.

from repro.configs.registry import get_config


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def f32(cfg):
    """CPU tests run in float32 (bf16 is slow + noisy on host)."""
    return dataclasses.replace(cfg, dtype="float32")


@pytest.fixture(scope="session")
def toy_cfg():
    return f32(get_config("toy-2m"))
