"""Property-based tests (hypothesis) for the paper's Theorem 1 invariants:

1. Sandwich: min(pi_b, pi_t) <= pi_prox <= max(pi_b, pi_t)
2. Contractive closed form: pi_t/pi_prox == (pi_t/pi_b)^alpha
3. Variance contraction: Var[w^alpha] decreases as staleness grows
plus system invariants (masking, group normalization).
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import RLConfig
from repro.core.a3po import alpha_from_staleness, compute_prox_logp_approximation
from repro.core.advantages import group_normalized_advantages
from repro.core.losses import policy_loss

logp_arrays = st.lists(
    st.floats(min_value=-20.0, max_value=-1e-3), min_size=1, max_size=32)
staleness_vals = st.integers(min_value=0, max_value=100)


@settings(max_examples=60, deadline=None)
@given(logp_arrays, logp_arrays, staleness_vals)
def test_sandwich_property(behav, target, d):
    """Theorem 1.1: pi_prox lies between pi_behav and pi_theta."""
    n = min(len(behav), len(target))
    b = jnp.array(behav[:n])[None, :]
    t = jnp.array(target[:n])[None, :]
    prox = compute_prox_logp_approximation(
        b, t, jnp.array([0]), d)
    lo = jnp.minimum(b, t) - 1e-5
    hi = jnp.maximum(b, t) + 1e-5
    assert bool(jnp.all(prox >= lo)), (prox, lo)
    assert bool(jnp.all(prox <= hi)), (prox, hi)


@settings(max_examples=60, deadline=None)
@given(logp_arrays, logp_arrays, st.integers(min_value=1, max_value=50))
def test_contractive_closed_form(behav, target, d):
    """Theorem 1.2: r = pi_t/pi_prox = w^alpha."""
    n = min(len(behav), len(target))
    b = np.array(behav[:n])
    t = np.array(target[:n])
    prox = np.asarray(compute_prox_logp_approximation(
        jnp.array(b)[None], jnp.array(t)[None], jnp.array([0]), d))[0]
    alpha = 1.0 / d
    r = np.exp(t - prox)
    w_alpha = np.exp(alpha * (t - b))
    np.testing.assert_allclose(r, w_alpha, rtol=1e-5, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_ratio_tends_to_one_with_staleness(seed):
    """Theorem 1.2 limit: r -> 1 as d -> infinity."""
    rng = np.random.default_rng(seed)
    b = -rng.uniform(0.1, 10.0, size=16)
    t = -rng.uniform(0.1, 10.0, size=16)
    for d_small, d_big in [(1, 10), (10, 1000)]:
        r_small = np.exp(
            (t - b) * float(alpha_from_staleness(jnp.array(float(d_small)))))
        r_big = np.exp(
            (t - b) * float(alpha_from_staleness(jnp.array(float(d_big)))))
        assert np.all(np.abs(np.log(r_big)) <= np.abs(np.log(r_small)) + 1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_variance_contraction(seed):
    """Theorem 1.2: Var[w^alpha] vanishes as d grows."""
    rng = np.random.default_rng(seed)
    w = np.exp(rng.normal(0, 1.0, size=512))  # lognormal IS weights
    variances = []
    for d in [1, 2, 5, 20, 100]:
        alpha = 1.0 / d
        variances.append(np.var(w ** alpha))
    assert all(v2 <= v1 + 1e-9
               for v1, v2 in zip(variances, variances[1:])), variances
    assert variances[-1] < 0.05 * variances[0] + 1e-9


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=8), st.integers(0, 10_000))
def test_alpha_monotone_decreasing(n, seed):
    """Eq. 4: alpha monotonically decreases in d (fresher data weighted
    more toward behavior)."""
    d = jnp.arange(1, n + 1, dtype=jnp.float32)
    a = np.asarray(alpha_from_staleness(d))
    assert np.all(np.diff(a) <= 1e-9)
    assert np.all((a > 0) & (a <= 1.0))


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=6), st.integers(0, 10_000))
def test_group_norm_invariants(groups, seed):
    rng = np.random.default_rng(seed)
    g = 4
    r = rng.uniform(0, 1, size=groups * g).astype(np.float32)
    adv = np.asarray(group_normalized_advantages(jnp.array(r), g))
    adv_g = adv.reshape(groups, g)
    np.testing.assert_allclose(adv_g.mean(axis=1), 0.0, atol=1e-5)
    assert np.all(np.isfinite(adv))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_masked_tokens_have_no_gradient_influence(seed):
    """Loss is invariant to values at masked positions."""
    import jax
    rng = np.random.default_rng(seed)
    B, T = 4, 8
    cfg = RLConfig()
    mask = (rng.uniform(size=(B, T)) > 0.5).astype(np.float32)
    behav = jnp.array(-rng.uniform(0.1, 5, (B, T)), jnp.float32)
    adv = jnp.array(rng.normal(size=(B, T)), jnp.float32)
    logp = jnp.array(-rng.uniform(0.1, 5, (B, T)), jnp.float32)
    garbage = jnp.where(mask > 0, logp, logp * 7 - 3)
    vs = jnp.array(rng.integers(0, 3, B), jnp.int32)
    l1, _ = policy_loss("loglinear", logp, behav, adv * mask,
                        jnp.array(mask), cfg, versions=vs, current_version=5)
    l2, _ = policy_loss("loglinear", garbage, behav, adv * mask,
                        jnp.array(mask), cfg, versions=vs, current_version=5)
    if mask.sum() > 0:
        np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-6)
