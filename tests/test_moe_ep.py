"""Expert-parallel shard_map MoE vs the GSPMD oracle on a real multi-device
mesh (subprocess: needs XLA_FLAGS device-count override before jax init)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from jax.sharding import Mesh
    from repro.configs.registry import get_config
    from repro.distributed.sharding import ShardingEnv, use_sharding
    from repro.models import moe as moe_mod
    from repro.models.params import init_from_specs

    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b-reduced"),
                              dtype="float32")
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    env = ShardingEnv(mesh)
    env.ep_shard_map = True
    params = init_from_specs(moe_mod.moe_spec(cfg), jax.random.PRNGKey(0),
                             jnp.float32)
    B, S, d = 4, 20, cfg.d_model   # S=20 exercises the seq-padding path
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.5
    y_ref, _ = moe_mod.moe_apply_gspmd(params, x, cfg)
    with mesh, use_sharding(env):
        y_ep, _ = jax.jit(lambda p, x: moe_mod.moe_apply(p, x, cfg))(params, x)
    err = float(jnp.abs(y_ref - y_ep).max() / (jnp.abs(y_ref).max() + 1e-9))
    assert err < 2e-3, f"EP mismatch: {err}"
    print("EP_OK", err)
""")


def test_ep_dispatch_matches_gspmd_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "EP_OK" in out.stdout, out.stdout + out.stderr
