"""Property-based tests (hypothesis) for the admission scheduler.

Invariants, over randomized traces:
1. Pops respect (priority, arrival) order — lower class first, FIFO
   within a class — and aging only ever promotes (never reorders within
   the promoted set).
2. Nothing is admitted past the staleness budget ``d_max``; every budget
   drop carries ``drop_reason="staleness_budget"``.
3. A request is requeued at most ``max_preempts`` times; past the budget
   it is dropped with ``drop_reason="max_preempts"``.
4. Random alloc/share/release sequences against the real
   ``BlockAllocator`` restore the free list exactly (the preempt-path
   accounting the control plane relies on).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.rollout.continuous import Request
from repro.rollout.paged_cache import BlockAllocator
from repro.serving import AdmissionScheduler, SchedulerConfig


class _StubEngine:
    """Just the admission surface: unlimited blocks, no jax."""

    class _Alloc:
        n_free = 1 << 20

    allocator = _Alloc()

    def blocks_needed(self, prompt, max_new):
        return 1


def _req(rid, *, priority=0, submit_version=0):
    return Request(rid, np.arange(4, 12, dtype=np.int32), 4,
                   priority=priority, submit_version=submit_version)


def _drain(sched, now_version=0, now_s=0.0):
    out = []
    while True:
        got = sched.pop_admissible(now_version, engine=_StubEngine(),
                                   now_s=now_s)
        if got is None:
            break
        out.append(got[0])
    return out


priorities = st.lists(st.integers(min_value=0, max_value=3),
                      min_size=1, max_size=32)


@settings(max_examples=60, deadline=None)
@given(priorities)
def test_pop_order_is_priority_then_arrival(prios):
    sched = AdmissionScheduler(SchedulerConfig(d_max=1 << 30))
    for i, p in enumerate(prios):
        sched.enqueue(_req(i, priority=p))
    popped = _drain(sched)
    assert len(popped) == len(prios)
    keys = [(r.priority, r.rid) for r in popped]
    assert keys == sorted(keys)


@settings(max_examples=60, deadline=None)
@given(priorities, st.floats(min_value=0.1, max_value=10.0))
def test_aging_promotes_but_never_loses_requests(prios, age):
    """With aging on, a drain at a late clock still pops every request
    exactly once, aged entries ahead of younger non-urgent ones."""
    sched = AdmissionScheduler(
        SchedulerConfig(d_max=1 << 30, age_promote_s=age))
    for i, p in enumerate(prios):
        sched.enqueue(_req(i, priority=p), now_s=0.0)
    late = _req(len(prios), priority=3)
    sched.enqueue(late, now_s=age)  # too young to age at drain time
    popped = _drain(sched, now_s=age)  # originals all aged to prio 0
    assert sorted(r.rid for r in popped) == list(range(len(prios) + 1))
    # every original (aged -> prio 0 or already 0) precedes the young
    # non-urgent late arrival; FIFO preserved among the aged
    if late.priority > 0:
        assert popped[-1].rid == late.rid
    aged_rids = [r.rid for r in popped[:-1]]
    assert aged_rids == sorted(aged_rids)


versions = st.lists(st.integers(min_value=0, max_value=20),
                    min_size=1, max_size=32)


@settings(max_examples=60, deadline=None)
@given(versions, st.integers(min_value=0, max_value=20),
       st.integers(min_value=0, max_value=8))
def test_never_admits_past_staleness_budget(subs, now_version, d_max):
    sched = AdmissionScheduler(SchedulerConfig(d_max=d_max))
    for i, v in enumerate(subs):
        sched.enqueue(_req(i, submit_version=v))
    popped = _drain(sched, now_version=now_version)
    dropped = sched.take_dropped()
    assert len(popped) + len(dropped) == len(subs)
    for r in popped:
        assert now_version - r.submit_version <= d_max
    for r in dropped:
        assert now_version - r.submit_version > d_max
        assert r.drop_reason == "staleness_budget"


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=5))
def test_max_preempts_is_a_hard_cap(max_preempts):
    sched = AdmissionScheduler(
        SchedulerConfig(d_max=1 << 30, max_preempts=max_preempts))
    req = _req(0)
    sched.enqueue(req)
    requeues = 0
    while True:
        got = sched.pop_admissible(0, engine=_StubEngine())
        assert got is not None
        action = sched.handle_preempted(got[0], 0)
        if action == "drop":
            break
        requeues += 1
        assert requeues <= max_preempts
    assert requeues == max_preempts
    dropped = sched.take_dropped()
    assert dropped[0].drop_reason == "max_preempts"
    assert dropped[0].preempt_count == max_preempts + 1


# random alloc/share/release programs against the real allocator: the
# preempt path's block accounting (release every refcounted block) must
# restore the free list exactly, regardless of sharing structure
programs = st.lists(
    st.tuples(st.integers(min_value=1, max_value=4),   # blocks to alloc
              st.booleans()),                          # share one block?
    min_size=1, max_size=16)


@settings(max_examples=60, deadline=None)
@given(programs, st.randoms(use_true_random=False))
def test_allocator_roundtrip_under_sharing(prog, rnd):
    alloc = BlockAllocator(n_blocks=128)
    free0 = alloc.n_free
    held = []  # per-request block lists (with shared refs duplicated)
    for n, share in prog:
        blocks = alloc.alloc(n)
        if share and held:
            donor = rnd.choice(held)
            b = donor[0]
            alloc.incref(b)
            blocks = blocks + [b]
        held.append(blocks)
    assert alloc.n_free < free0
    rnd.shuffle(held)  # preemptions land in arbitrary order
    for blocks in held:
        for b in blocks:
            alloc.decref(b)
    assert alloc.n_free == free0
    assert alloc.refcount == {}
