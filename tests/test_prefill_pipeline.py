"""Chunked paged prefill lane: parity vs the dense whole-sequence path,
bucket-ladder compile behavior, packed launches, mid-prefill interrupts,
and decode-lane non-starvation under long prompts."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.async_rl.weights import WeightStore
from repro.configs.registry import get_config
from repro.models import model as M
from repro.rollout.continuous import ContinuousBatchingEngine, Request
from repro.serving import (
    AdmissionScheduler,
    RadixPrefixCache,
    SchedulerConfig,
    ServingControlPlane,
)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, **kw):
    base = dict(max_seqs=2, block_size=4, n_blocks=64, max_blocks_per_seq=16,
                greedy=True)
    base.update(kw)
    return ContinuousBatchingEngine(cfg, **base)


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(4, cfg.vocab_size, size=n).astype(np.int32)


def _drain(eng, params, key, n, max_steps=200):
    done = []
    while len(done) < n:
        key, sub = jax.random.split(key)
        done += eng.step(params, sub)
        max_steps -= 1
        assert max_steps > 0, "engine did not finish"
    return done


# ------------------------------------------------------------------- parity
def test_chunked_matches_dense_whole_sequence(setup):
    """Greedy generations through the chunked prefill lane equal the
    dense whole-sequence prefill for prompts spanning several chunk
    boundaries (incl. slot reuse), and the pool drains clean."""
    cfg, params = setup
    prompts = [_prompt(cfg, n, seed=n) for n in (5, 9, 13, 24)]
    max_new = 6

    gens = {}
    for mode in ("dense", "chunked"):
        eng = _engine(cfg, n_blocks=64, prefill_mode=mode, prefill_chunk=8)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        done = eng.run(params, jax.random.PRNGKey(2))
        gens[mode] = {r.rid: r.generated for r in done}
        assert eng.allocator.n_free == 64 - 1  # minus reserved scratch
    assert gens["chunked"] == gens["dense"]

    # sampling-point logits agree tightly (chunk batches the same math
    # the per-token replay runs row by row)
    e_d = _engine(cfg, prefill_mode="dense")
    e_c = _engine(cfg, prefill_mode="chunked", prefill_chunk=8)
    e_d.admit_request(params, 0, Request(1, prompts[3], max_new))
    e_c.admit_request(params, 0, Request(1, prompts[3], max_new))
    np.testing.assert_allclose(np.asarray(e_c._next_logits[0]),
                               np.asarray(e_d._next_logits[0]),
                               rtol=1e-5, atol=1e-5)


def test_chunked_prefill_with_radix_hits_matches_uncached(setup):
    """A radix prefix hit entering the chunk lane (prefill resumes at the
    matched cursor) yields the exact generation of an uncached chunked
    prefill, and decode steps running concurrently with the mid-prefill
    slot never corrupt the shared blocks."""
    cfg, params = setup
    prompt = _prompt(cfg, 12, seed=7)
    max_new = 4

    eng = _engine(cfg, prefill_chunk=8)
    eng.prefix_cache = RadixPrefixCache(eng.allocator, eng.state.block_size)
    eng.admit_request(params, 0, Request(1, prompt, max_new))

    # second admit: radix match maps 11 of 12 prompt tokens; only map
    # pages here — leave the slot mid-prefill (cursor at the hit)
    req2 = Request(2, prompt, max_new)
    eng.start_prefill(1, req2, version=0)
    assert req2.prefix_hit_tokens == 11
    assert req2.prefill_pos == 11 and not req2.prefill_done

    # decode the ready slot while slot 1 is mid-prefill on shared pages:
    # its decode-lane writes must be redirected to scratch
    key = jax.random.PRNGKey(3)
    for _ in range(2):
        key, sub = jax.random.split(key)
        eng.step(params, sub)
    assert len(eng.slots[0].generated) == 2
    assert not req2.generated  # mid-prefill slot never decoded

    # finish the prefill, then drain both
    while not req2.prefill_done:
        eng.prefill_step(params)
    done = _drain(eng, params, key, 2)
    gen = {r.rid: r.generated for r in done}

    # uncached chunked reference
    ref = _engine(cfg, prefill_chunk=8)
    ref.admit_request(params, 0, Request(1, prompt, max_new))
    ref.admit_request(params, 1, Request(2, prompt, max_new))
    ref_done = _drain(ref, params, jax.random.PRNGKey(3), 2)
    ref_gen = {r.rid: r.generated for r in ref_done}
    assert gen == ref_gen


def test_packed_chunk_bit_exact_vs_solo(setup):
    """Two short prompts packed into one chunk launch produce logits
    bit-identical to prefilling each alone (segment isolation)."""
    cfg, params = setup
    p1, p2 = _prompt(cfg, 5, seed=1), _prompt(cfg, 6, seed=2)
    eng = _engine(cfg, prefill_chunk=16)
    eng.start_prefill(0, Request(1, p1, 4))
    eng.start_prefill(1, Request(2, p2, 4))
    assert eng.prefill_step(params) == 1  # one packed launch covers both
    assert eng.slots[0].prefill_done and eng.slots[1].prefill_done

    for slot, p in ((0, p1), (1, p2)):
        solo = _engine(cfg, prefill_chunk=16)
        solo.admit_request(params, 0, Request(1, p, 4))
        np.testing.assert_array_equal(np.asarray(eng._next_logits[slot]),
                                      np.asarray(solo._next_logits[0]))


# ------------------------------------------------------------ bucket ladder
def test_chunk_bucket_ladder_single_compile(setup):
    """Distinct prompt lengths landing in the same chunk bucket reuse one
    compiled chunk step: the cache-miss counter stays at 1."""
    cfg, params = setup
    eng = _engine(cfg, max_seqs=4, prefill_chunk=8)
    # lengths 3..6 all pad to the bottom bucket (8)
    for i, n in enumerate((3, 4, 5, 6)):
        eng.admit_request(params, i, Request(i + 1, _prompt(cfg, n, seed=n),
                                             2))
    assert eng.prefill_compiles == 1, eng._prefill_shapes
    assert eng.prefill_launches >= 1


def test_dense_bucket_ladder_single_compile(setup):
    """The dense fallback pads to its bucket too: lengths within one
    bucket compile the whole-sequence prefill once."""
    cfg, params = setup
    eng = _engine(cfg, max_seqs=4, prefill_mode="dense", prefill_chunk=8)
    for i, n in enumerate((9, 11, 13, 15)):  # all pad to 16
        eng.admit_request(params, i, Request(i + 1, _prompt(cfg, n, seed=n),
                                             2))
    assert eng.prefill_compiles == 1, eng._prefill_shapes


# ------------------------------------------------- control-plane behaviors
def test_publish_mid_prefill_resumes_and_stamps(setup):
    """A weight publish landing while a prompt is mid-prefill: the cursor
    carries over, the request completes, and every generated token is
    stamped with the new version."""
    cfg, params = setup
    store = WeightStore(params, 0)
    eng = _engine(cfg, prefill_chunk=8)
    cp = ServingControlPlane(eng, store,
                             AdmissionScheduler(SchedulerConfig(d_max=100)),
                             prefill_budget=1)
    prompt = _prompt(cfg, 30, seed=4)
    rid = cp.submit(prompt, max_new=3)
    key = jax.random.PRNGKey(5)
    published = False
    done = []
    for step in range(60):
        key, sub = jax.random.split(key)
        done += cp.step(sub)
        req = eng.slots.get(0)
        if not published and req is not None and not req.prefill_done:
            # same params, new version: a pure re-stamp mid-prefill
            store.publish(params, 2)
            published = True
        if done:
            break
    assert published and done and done[0].rid == rid
    assert len(done[0].generated) == 3
    # prefill resumed under v2 -> every sampled token stamped v2
    assert done[0].token_versions == [2, 2, 2]


def test_decode_lane_not_starved_by_long_prompt(setup):
    """With a bounded per-step chunk budget, a short request admitted
    alongside a long prompt finishes while the long prompt is still
    prefilling — the decode lane keeps emitting between chunks."""
    cfg, params = setup
    store = WeightStore(params, 0)
    eng = _engine(cfg, prefill_chunk=8)
    cp = ServingControlPlane(eng, store,
                             AdmissionScheduler(SchedulerConfig(d_max=100)),
                             prefill_budget=1)
    rid_long = cp.submit(_prompt(cfg, 40, seed=8), max_new=2)
    rid_short = cp.submit(_prompt(cfg, 5, seed=9), max_new=3)
    key = jax.random.PRNGKey(6)
    finished = {}
    long_pending_at_short_finish = False
    for step in range(80):
        key, sub = jax.random.split(key)
        for r in cp.step(sub):
            finished[r.rid] = r
            if r.rid == rid_short:
                long_req = next(
                    (q for q in eng.slots.values()
                     if q is not None and q.rid == rid_long), None)
                long_pending_at_short_finish = (
                    long_req is not None and not long_req.prefill_done)
        if len(finished) == 2:
            break
    assert set(finished) == {rid_long, rid_short}
    # the short request must complete strictly before the long prompt's
    # prefill does (shortest-remaining-first packing + budget bound)
    assert long_pending_at_short_finish
    assert len(finished[rid_long].generated) == 2
    # prefill-lane telemetry flowed into the metrics
    snap = cp.metrics.snapshot()
    assert snap["prefill_chunks"] >= 6  # ceil((40-8+5)/8)+... several
    assert snap["ttft_s_count"] == 2.0
    assert snap["ttft_s_max"] > 0.0
