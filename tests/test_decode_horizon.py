"""Fused decode horizon: parity with the per-token loop (greedy + seeded
sampling), EOS / budget handling mid-horizon, and host-sync accounting
surfaced through ServingMetrics (the StepRecord.serving payload)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.async_rl.weights import WeightStore
from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.data import tokenizer as tok
from repro.models import model as M
from repro.rollout.continuous import ContinuousBatchingEngine
from repro.serving import (
    AdmissionScheduler,
    SchedulerConfig,
    ServingControlPlane,
)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, **kw):
    base = dict(max_seqs=4, block_size=4, n_blocks=64,
                max_blocks_per_seq=16, rl=RLConfig(top_p=0.9))
    base.update(kw)
    return ContinuousBatchingEngine(cfg, **base)


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, cfg.vocab_size,
                         size=rng.integers(5, 13)).astype(np.int32)
            for _ in range(n)]


def _all_requests(engine, done):
    reqs = {r.rid: r for r in done}
    reqs.update({r.rid: r for r in engine.slots.values() if r is not None})
    return reqs


def test_horizon_matches_per_token_greedy(setup):
    """Full run() — admission, slot reuse, release — is bit-identical
    between decode_horizon=1 (per-token) and a fused 8-token horizon."""
    cfg, params = setup
    prompts = _prompts(cfg, 6)
    outs = {}
    for H in (1, 8):
        srv = _engine(cfg, greedy=True, decode_horizon=H)
        for p in prompts:
            srv.submit(p, max_new=12)
        done = srv.run(params, jax.random.PRNGKey(1))
        assert len(done) == len(prompts)
        # every page back in the pool (minus the reserved scratch block)
        assert srv.allocator.n_free == 64 - 1
        outs[H] = {r.rid: r for r in done}
    for rid, a in outs[1].items():
        b = outs[8][rid]
        assert a.generated == b.generated
        np.testing.assert_array_equal(np.float32(a.gen_logp),
                                      np.float32(b.gen_logp))
        assert a.token_versions == b.token_versions
    # the fused path drained once per launch, the baseline twice per token
    # (host_syncs counts blocking decode-path transfers)
    assert outs  # engines are gone; counters checked in the sampled test


def test_horizon_matches_per_token_sampled(setup):
    """Seeded sampling: one fused horizon == H per-token steps under the
    same key schedule (key, sub = split(key) per token), bit-exact in
    tokens, behavior logps, and version stamps."""
    cfg, params = setup
    prompts = _prompts(cfg, 4, seed=3)
    H = 8
    ref = _engine(cfg, decode_horizon=1)
    fus = _engine(cfg, decode_horizon=H)
    for p in prompts:
        ref.submit(p, max_new=H)
        fus.submit(p, max_new=H)
    ref._admit(params)
    fus._admit(params)
    key = jax.random.PRNGKey(11)
    done_f = fus.step_horizon(params, key, version=7)
    done_r, k = [], key
    for _ in range(H):
        if not any(r is not None for r in ref.slots.values()):
            break
        k, sub = jax.random.split(k)
        done_r += ref.step(params, sub, version=7)
    reqs_r = _all_requests(ref, done_r)
    reqs_f = _all_requests(fus, done_f)
    assert set(reqs_r) == set(reqs_f) == {1, 2, 3, 4}
    for rid, a in reqs_r.items():
        b = reqs_f[rid]
        assert a.generated == b.generated
        np.testing.assert_array_equal(np.float32(a.gen_logp),
                                      np.float32(b.gen_logp))
        # first horizon token stamped with the admit-time version (0),
        # the rest with the decoding params' version (7)
        assert a.token_versions == b.token_versions
        assert b.token_versions[0] == 0
        assert all(v == 7 for v in b.token_versions[1:])
    # host-sync shape of the two paths: 1 drain per horizon vs 2 per token
    assert fus.host_syncs == fus.decode_launches == 1
    assert ref.host_syncs == 2 * ref.decode_launches


def test_eos_mid_horizon_masks_and_releases(setup):
    """A slot hitting EOS inside the horizon emits exactly through EOS
    (mask 0 afterwards), releases its pages at the boundary, and never
    perturbs the other slots."""
    cfg, params = setup
    srv = _engine(cfg, greedy=True, decode_horizon=8)
    p1, p2 = _prompts(cfg, 2, seed=5)
    srv.submit(p1, max_new=8)
    srv.submit(p2, max_new=12)
    srv._admit(params)
    free_before = srv.allocator.n_free
    # force slot 0's next sampled token to be EOS: done-masking must hold
    # for the remaining 7 in-horizon steps
    boost = jnp.zeros((cfg.vocab_size,), jnp.float32).at[tok.EOS].set(1e9)
    srv._next_logits = srv._next_logits.at[0].add(boost)
    done = srv.step_horizon(params, jax.random.PRNGKey(0))
    assert [r.rid for r in done] == [1]
    r = done[0]
    assert r.done and r.generated == [tok.EOS]
    assert len(r.gen_logp) == len(r.token_versions) == 1
    assert srv.slots[0] is None  # released at the horizon boundary
    assert srv.allocator.n_free > free_before
    # the surviving slot decoded a full horizon in the same launch
    r2 = srv.slots[1]
    assert r2 is not None and len(r2.generated) == 8
    assert srv.host_syncs == 1


def test_budget_exhaustion_mid_horizon(setup):
    """A request whose remaining max_new is shorter than the horizon stops
    emitting at its budget and finishes in one launch."""
    cfg, params = setup
    srv = _engine(cfg, greedy=True, decode_horizon=8)
    (p,) = _prompts(cfg, 1, seed=7)
    srv.submit(p, max_new=3)
    srv._admit(params)
    done = srv.step_horizon(params, jax.random.PRNGKey(0))
    assert len(done) == 1 and done[0].done
    assert 1 <= len(done[0].generated) <= 3  # EOS may land earlier
    assert srv.allocator.n_free == 64 - 1
    assert srv.host_syncs == 1


def test_horizon_view_branch_matches_paged_branch(setup):
    """The off-TPU contiguous-view horizon and the per-token paged-op
    horizon (the TPU branch, here via the XLA-gather dispatch) produce
    identical drains, pools, lengths, and next logits."""
    from repro.rollout.continuous import _paged_decode_horizon

    cfg, params = setup
    srv = _engine(cfg, decode_horizon=8)
    for p in _prompts(cfg, 3, seed=13):
        srv.submit(p, max_new=8)
    srv._admit(params)
    budget = np.zeros((srv.max_seqs,), np.int32)
    for s, r in srv.slots.items():
        if r is not None:
            budget[s] = 8
    st = srv.state
    outs = {}
    for use_view in (True, False):
        outs[use_view] = _paged_decode_horizon(
            params, cfg, jnp.array(st.pool_k), jnp.array(st.pool_v),
            st.block_tables, st.seq_lens, srv._next_logits,
            jnp.asarray(budget), jax.random.PRNGKey(4),
            trash_block=srv.trash_block, horizon=8,
            temperature=1.0, top_p=1.0, greedy=False, use_view=use_view)
    packed_v, pk_v, pv_v, lens_v, logits_v = outs[True]
    packed_p, pk_p, pv_p, lens_p, logits_p = outs[False]
    np.testing.assert_array_equal(np.asarray(packed_v),
                                  np.asarray(packed_p))
    np.testing.assert_array_equal(np.asarray(lens_v), np.asarray(lens_p))
    np.testing.assert_array_equal(np.asarray(logits_v),
                                  np.asarray(logits_p))
    # live pages agree; scratch-block garbage differs by construction
    tables = np.asarray(st.block_tables)
    live = sorted({int(b) for b in tables.ravel() if b >= 0}
                  - {srv.trash_block})
    np.testing.assert_array_equal(np.asarray(pk_v)[:, live],
                                  np.asarray(pk_p)[:, live])
    np.testing.assert_array_equal(np.asarray(pv_v)[:, live],
                                  np.asarray(pv_p)[:, live])


def test_control_plane_horizon_host_sync_accounting(setup):
    """The StepRecord.serving payload (ServingMetrics.snapshot) exposes
    the fused path's sync shape: exactly one host drain per decode launch
    and well under one sync per token."""
    cfg, params = setup
    store = WeightStore(params, 0)
    eng = _engine(cfg, decode_horizon=8)
    cp = ServingControlPlane(eng, store,
                             AdmissionScheduler(SchedulerConfig(d_max=100)))
    prompts = _prompts(cfg, 4, seed=9)
    pad = max(len(p) for p in prompts)
    batch = np.zeros((4, pad), np.int32)
    lengths = np.zeros((4,), np.int32)
    for i, p in enumerate(prompts):
        batch[i, : len(p)] = p
        lengths[i] = len(p)
    rb = cp.generate_batch(batch, lengths, jax.random.PRNGKey(2),
                           max_new=16)
    assert rb.gen_mask.sum() > 0
    snap = cp.metrics.snapshot()
    assert snap["decode_tokens"] == float(rb.gen_mask.sum())
    # <= 1 host sync per horizon (acceptance criterion), amortized over
    # up to max_seqs * horizon tokens per drain
    assert snap["decode_host_syncs"] == snap["decode_launches"]
    assert snap["host_syncs_per_token"] < 1.0
    assert snap["decode_tokens_per_s"] > 0.0
