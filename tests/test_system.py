"""End-to-end system behaviour tests: rollout engine, trainer, async
orchestration, checkpointing, sharding rules."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RLConfig
from repro.configs.registry import get_config, list_archs
from repro.data import tokenizer as tok
from repro.data.tasks import ArithmeticTask
from repro.rollout.engine import RolloutEngine
from repro.training.checkpoints import load_checkpoint, save_checkpoint
from repro.training.trainer import (
    Trainer,
    assemble_train_batch,
    recompute_prox_logp,
    score_tokens,
)


@pytest.fixture(scope="module")
def toy():
    return dataclasses.replace(get_config("toy-2m"), dtype="float32")


@pytest.fixture(scope="module")
def task():
    return ArithmeticTask(max_operand=9, n_terms=2, prompt_len=8, seed=0)


@pytest.fixture(scope="module")
def rl():
    return RLConfig(group_size=4, num_minibatches=2, learning_rate=3e-4)


def test_registry_covers_assignment():
    archs = list_archs(assigned_only=True)
    assert len(archs) == 10
    families = {get_config(a).arch_type for a in archs}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


def test_tokenizer_roundtrip():
    text = "12+34=46"
    assert tok.decode(tok.encode(text)) == text


def test_task_rewards_verifiable(task):
    b = task.sample(4)
    for i, ans in enumerate(b.answers):
        ids = tok.encode(ans) + [tok.EOS]
        assert task.reward(np.array(ids), ans) == 1.0
        assert task.reward(np.array(tok.encode("999")), ans) == 0.0


def test_rollout_engine_contract(toy, task, rl):
    engine = RolloutEngine(toy, rl, max_new_tokens=4)
    params = Trainer(toy, rl).init_state(jax.random.PRNGKey(0)).params
    b = task.sample(3)
    rb = engine.generate(params, b.prompts, b.prompt_lengths,
                         jax.random.PRNGKey(1), version=5)
    assert rb.version == 5
    assert rb.tokens.shape == (3, 8 + 4)
    assert rb.gen_logp.shape == (3, 4)
    # behavior logps must be valid log-probabilities at sampled tokens
    assert np.all(rb.gen_logp <= 1e-5)
    # mask is a prefix (1s then 0s)
    for row in rb.gen_mask:
        assert np.all(np.diff(row) <= 0)


def test_behavior_logp_matches_scoring(toy, task, rl):
    """Rollout-engine behavior logps == trainer scoring of the same tokens
    (no behav/target numerical mismatch, unlike vLLM-vs-trainer gaps)."""
    engine = RolloutEngine(toy, rl, max_new_tokens=4)
    params = Trainer(toy, rl).init_state(jax.random.PRNGKey(0)).params
    b = task.sample(2)
    rb = engine.generate(params, b.prompts, b.prompt_lengths,
                         jax.random.PRNGKey(1))
    tb = assemble_train_batch([rb], np.zeros(2, np.float32))
    logp, _, _ = score_tokens(params, toy, tb.tokens)
    sel = tb.response_mask > 0
    np.testing.assert_allclose(np.asarray(logp)[sel],
                               np.asarray(tb.behav_logp)[sel],
                               rtol=1e-4, atol=1e-4)


def test_assemble_scatters_correctly(toy, task, rl):
    engine = RolloutEngine(toy, rl, max_new_tokens=4)
    params = Trainer(toy, rl).init_state(jax.random.PRNGKey(2)).params
    b = task.sample(2)
    rb = engine.generate(params, b.prompts, b.prompt_lengths,
                         jax.random.PRNGKey(3), version=2)
    tb = assemble_train_batch([rb], np.ones(2, np.float32))
    for i in range(2):
        L = int(b.prompt_lengths[i])
        n = int(rb.gen_mask[i].sum())
        row_mask = np.asarray(tb.response_mask[i])
        assert row_mask[L - 1: L - 1 + n].sum() == n
        assert row_mask.sum() == n
    assert np.all(np.asarray(tb.versions) == 2)


@pytest.mark.parametrize("method", ["loglinear", "recompute", "sync"])
def test_trainer_step_all_methods(toy, task, rl, method):
    trainer = Trainer(toy, rl, method)
    state = trainer.init_state(jax.random.PRNGKey(0))
    engine = RolloutEngine(toy, rl, max_new_tokens=4)
    b = task.sample(4)
    prompts = np.repeat(b.prompts, rl.group_size, axis=0)
    lengths = np.repeat(b.prompt_lengths, rl.group_size)
    rb = engine.generate(state.params, prompts, lengths,
                         jax.random.PRNGKey(1), version=0)
    rewards = np.random.default_rng(0).uniform(size=16).astype(np.float32)
    tb = assemble_train_batch([rb], rewards)
    state2, m = trainer.step(state, tb)
    assert int(state2.version) == 1
    assert np.isfinite(m["loss"])
    assert m["prox_time_s"] >= 0
    if method == "recompute":
        assert m["prox_time_s"] > 0


def test_recompute_prox_is_score(toy, rl):
    params = Trainer(toy, rl).init_state(jax.random.PRNGKey(0)).params
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 4, 20)
    prox = recompute_prox_logp(params, toy, toks)
    logp, _, _ = score_tokens(params, toy, toks)
    np.testing.assert_allclose(prox, logp, rtol=1e-6)


def test_checkpoint_roundtrip(toy, rl):
    trainer = Trainer(toy, rl)
    state = trainer.init_state(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save_checkpoint(path, {"params": state.params, "opt": state.opt},
                        {"version": 3})
        tree, meta = load_checkpoint(path)
        assert meta["version"] == 3
        restored = tree["params"]
        flat_a = jax.tree.leaves(state.params)
        flat_b = jax.tree.leaves(restored)
        assert len(flat_a) == len(flat_b)
        for a, b in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_simulation_staleness(toy, task, rl):
    from repro.async_rl.orchestrator import simulate_async
    _, recs = simulate_async(toy, rl, task, "loglinear", num_steps=4,
                             n_prompts=2, max_new_tokens=3, staleness=2)
    assert [r.staleness_mean for r in recs] == [0.0, 1.0, 2.0, 2.0]


def test_async_threaded_orchestrator(toy, task, rl):
    from repro.async_rl.orchestrator import AsyncOrchestrator
    orch = AsyncOrchestrator(toy, rl, task, "loglinear", n_prompts=2,
                             max_new_tokens=3, queue_capacity=2)
    trainer = Trainer(toy, rl, "loglinear")
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, recs = orch.run(state, num_steps=2)
    assert len(recs) == 2
    assert int(state.version) == 2
    assert all(np.isfinite(r.loss) for r in recs)


def test_rollout_queue_staleness_gate():
    from repro.async_rl.buffer import RolloutQueue
    from repro.rollout.engine import RolloutBatch
    q = RolloutQueue(capacity=4, max_staleness=2)

    def mk(version):
        return RolloutBatch(np.zeros((1, 4), np.int32), np.array([2]),
                            np.zeros((1, 2), np.float32),
                            np.ones((1, 2), np.float32), version=version)

    q.push(mk(0))
    q.push(mk(5))
    fresh = q.pop_fresh(current_version=6, n=1)
    assert fresh[0].version == 5  # version 0 was dropped (staleness 6 > 2)
    assert q.dropped == 1


def test_sharding_env_divisibility_fallback():
    """kv_heads=8 on model=16 must fall back to replication, not crash."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import ShardingEnv, abstract_mesh
    mesh = abstract_mesh((16, 16), ("data", "model"))
    env = ShardingEnv(mesh)
    # kv=8 not divisible by model=16 -> replicated
    assert env.spec((8, 128), ("kv_heads", "head_dim")) == P()
    # heads=96 divisible -> sharded
    assert env.spec((96, 128), ("heads", "head_dim")) == P("model")
    # FSDP weight: embed over data, ff over model
    assert env.spec((4096, 11008), ("embed", "ff")) == P("data", "model")
    # fsdp off -> embed replicated
    env2 = ShardingEnv(mesh, fsdp=False)
    assert env2.spec((4096, 11008), ("embed", "ff")) == P(None, "model")
    # batch spans (pod, data) on the multi-pod mesh
    mesh3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    env3 = ShardingEnv(mesh3)
    assert env3.spec((256, 4096), ("batch", "seq")) == P(("pod", "data"))
    # batch=1 (long_500k) -> replicated
    assert env3.spec((1, 4096), ("batch", "seq")) == P()


def test_constrain_noop_without_mesh():
    from repro.distributed.sharding import constrain
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)
    np.testing.assert_array_equal(x, y)


def test_restore_sharded_roundtrip(toy, rl):
    """Checkpoint restore onto mesh shardings (single-device local mesh)."""
    import tempfile
    from repro.launch.mesh import make_local_mesh
    from repro.distributed.sharding import ShardingEnv
    from repro.models import model as M
    from repro.training.checkpoints import restore_sharded, save_checkpoint

    trainer = Trainer(toy, rl)
    state = trainer.init_state(jax.random.PRNGKey(0))
    mesh = make_local_mesh()
    env = ShardingEnv(mesh)
    shardings = M.param_shardings(toy, env)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save_checkpoint(path, state.params, {"v": 1})
        restored, meta = restore_sharded(path, shardings)
    assert meta["v"] == 1
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_eval_hook_in_simulation(toy, task, rl):
    from repro.async_rl.orchestrator import simulate_async
    calls = []

    def fake_eval(params):
        calls.append(1)
        return 0.25

    _, recs = simulate_async(toy, rl, task, "loglinear", 4, n_prompts=2,
                             max_new_tokens=3, staleness=1,
                             eval_every=2, eval_fn=fake_eval)
    assert [r.eval_reward for r in recs] == [None, 0.25, None, 0.25]
    assert len(calls) == 2
