"""Load harness: seeded trace generation (determinism + JSONL roundtrip),
virtual-clock replay determinism, SLO shed/preempt policy, and the CLI.

The replay tests drive the real control plane + engine on the toy model;
the SLO policy tests exercise the scheduler against a stub engine (no
jax) so the shed/preempt decisions are tested in isolation.
"""
import math

import jax
import numpy as np
import pytest

from repro.loadgen.harness import CostModel, VirtualClock, run_trace
from repro.loadgen.slo import SLOAwareScheduler, SLOPolicy
from repro.loadgen.traces import (
    DEFAULT_CLASSES,
    SLOClass,
    TraceConfig,
    load_trace,
    prompt_tokens,
    save_trace,
    synthesize,
)
from repro.models import model as M
from repro.rollout.continuous import Request
from repro.serving import SchedulerConfig


@pytest.fixture(scope="module")
def params(toy_cfg):
    return M.init_params(toy_cfg, jax.random.PRNGKey(0))


SMALL = TraceConfig(seed=3, duration_s=0.8, rate_rps=10.0, burstiness=0.6,
                    publish_every_s=0.5)


# ------------------------------------------------------------------- traces
def test_synthesize_deterministic_and_roundtrip(tmp_path):
    a = synthesize(SMALL)
    b = synthesize(SMALL)
    assert a.requests == b.requests and a.publishes == b.publishes
    assert len(a.requests) > 0 and a.publishes  # non-trivial workload
    # same request seed -> same tokens; schema roundtrips through JSONL
    t1 = prompt_tokens(a.requests[0], 128)
    t2 = prompt_tokens(b.requests[0], 128)
    np.testing.assert_array_equal(t1, t2)
    path = tmp_path / "trace.jsonl"
    save_trace(str(path), a)
    c = load_trace(str(path))
    assert c.requests == a.requests and c.publishes == a.publishes
    assert c.classes == a.classes
    assert c.meta["seed"] == SMALL.seed


def test_synthesize_seed_changes_workload():
    a = synthesize(SMALL)
    b = synthesize(TraceConfig(**{**SMALL.__dict__, "seed": 4}))
    assert [r.t_arrival_s for r in a.requests] != \
        [r.t_arrival_s for r in b.requests]


# ------------------------------------------------------------------ harness
def test_replay_bit_deterministic(toy_cfg, params):
    """Two replays of the same trace produce identical lifecycle records
    and summaries — the acceptance bar for the committed JSONL."""
    trace = synthesize(SMALL)
    r1 = run_trace(toy_cfg, params, trace, policy="slo", max_seqs=2)
    r2 = run_trace(toy_cfg, params, trace, policy="slo", max_seqs=2)
    assert r1.records == r2.records
    assert r1.summary == r2.summary
    assert r1.steps == r2.steps
    # every submitted request reached a terminal outcome
    assert len(r1.records) == len(trace.requests)
    assert r1.summary["completed"] + r1.summary["dropped"] \
        == len(trace.requests)
    # lifecycle stamps are virtual and ordered
    for rec in r1.records:
        assert rec["t_submit_s"] >= rec["t_arrival_s"]
        if rec["outcome"] == "done":
            assert rec["t_done_s"] >= rec["t_first_token_s"] >= \
                rec["t_submit_s"] - 1e-9
            assert rec["tokens"] > 0


def test_replay_honors_publish_events(toy_cfg, params):
    """Weight-publish events advance the store version at their virtual
    timestamps; requests decoded after the publish carry fresher stamps."""
    trace = synthesize(SMALL)
    res = run_trace(toy_cfg, params, trace, policy="priority", max_seqs=2)
    assert res.summary["publishes"] == len(trace.publishes) == 1
    versions = {v for r in res.finished for v in r.token_versions}
    assert 1 in versions  # post-publish tokens stamped at v1


def test_virtual_clock_cost_model():
    clk = VirtualClock()
    cost = CostModel(step_overhead_s=0.01, prefill_chunk_s=0.1,
                     decode_token_s=0.001)
    clk.advance(cost.step_cost(chunks=2, tokens=8))
    assert clk.now == pytest.approx(0.01 + 0.2 + 0.008)
    clk.advance_to(0.1)  # never goes backwards
    assert clk.now == pytest.approx(0.218)


# --------------------------------------------------------------- SLO policy
class _StubEngine:
    """blocks_needed/allocator surface only — no jax, no pools."""

    class _Alloc:
        n_free = 1 << 20

    allocator = _Alloc()

    def blocks_needed(self, prompt, max_new):
        return 1


def _policy(**kw):
    base = dict(classes=DEFAULT_CLASSES, est_fixed_s=0.0,
                est_s_per_token=0.0)
    base.update(kw)
    return SLOPolicy(**base)


def _req(rid, *, priority=0, t_submit=0.0, prompt_len=8):
    r = Request(rid, np.arange(4, 4 + prompt_len, dtype=np.int32), 4,
                priority=priority)
    r.t_submit = t_submit
    return r


def test_slo_shed_past_deadline():
    """A queued request whose TTFT deadline has passed is shed (reason
    slo_shed), never admitted; one still inside its deadline pops."""
    sched = SLOAwareScheduler(SchedulerConfig(d_max=100), _policy())
    hopeless = _req(1, priority=0, t_submit=0.0)    # interactive: 0.25s
    viable = _req(2, priority=2, t_submit=0.9)      # bulk: 3.0s
    sched.enqueue(hopeless, now_s=0.0)
    sched.enqueue(viable, now_s=0.9)
    got = sched.pop_admissible(0, engine=_StubEngine(), now_s=1.0)
    assert got is not None and got[0].rid == 2
    assert sched.sheds == 1
    dropped = sched.take_dropped()
    assert [r.rid for r in dropped] == [1]
    assert dropped[0].drop_reason == "slo_shed"


def test_slo_shed_accounts_for_prefill_estimate():
    """Shedding is predictive: a request that would miss its deadline by
    the time prefill finishes is hopeless even before the deadline."""
    pol = _policy(est_fixed_s=0.0, est_s_per_token=0.01)  # 32 tok = 0.32s
    sched = SLOAwareScheduler(SchedulerConfig(d_max=100), pol)
    sched.enqueue(_req(1, priority=0, t_submit=0.0, prompt_len=32),
                  now_s=0.0)
    # now=0.1 < deadline=0.25, but 0.1 + 0.32 > 0.25 -> shed
    assert sched.pop_admissible(0, engine=_StubEngine(), now_s=0.1) is None
    assert sched.take_dropped()[0].drop_reason == "slo_shed"


def test_slo_overload_preemption_picks_lowest_class():
    """With no free slot and an urgent head-of-queue out of slack, the
    least-urgent in-flight slot is preempted with reason slo_overload."""
    sched = SLOAwareScheduler(SchedulerConfig(d_max=100), _policy())
    head = _req(1, priority=0, t_submit=0.0)  # deadline 0.25
    sched.enqueue(head, now_s=0.0)
    slots = {0: _req(10, priority=1, t_submit=0.0),
             1: _req(11, priority=2, t_submit=0.0),
             2: None}
    # slack = 0.25 - 0.2 = 0.05 < 0.25 * 0.25
    out = sched.check_preempt(slots, 0, now_s=0.2, free_slots=0)
    assert out == [1]
    assert sched.preempt_reasons[1] == "slo_overload"
    assert sched.slo_preempts == 1


def test_slo_no_preempt_with_free_slots_or_slack():
    sched = SLOAwareScheduler(SchedulerConfig(d_max=100), _policy())
    sched.enqueue(_req(1, priority=0, t_submit=0.0), now_s=0.0)
    slots = {0: _req(10, priority=2, t_submit=0.0)}
    # free slot available -> admission handles it, no preemption
    assert sched.check_preempt(slots, 0, now_s=0.2, free_slots=1) == []
    # plenty of slack -> no preemption either
    assert sched.check_preempt(slots, 0, now_s=0.01, free_slots=0) == []
    # equal-or-higher class in flight is never a victim
    sched2 = SLOAwareScheduler(SchedulerConfig(d_max=100), _policy())
    sched2.enqueue(_req(1, priority=1, t_submit=0.0), now_s=0.0)
    slots2 = {0: _req(10, priority=0, t_submit=0.0),
              1: _req(11, priority=1, t_submit=0.0)}
    assert sched2.check_preempt(slots2, 0, now_s=0.74,
                                free_slots=0) == []


def test_deadline_preserved_across_requeue():
    """A preempt-requeue keeps the original absolute deadline: the client
    has been waiting since the first submit."""
    sched = SLOAwareScheduler(
        SchedulerConfig(d_max=100, max_preempts=4), _policy())
    req = _req(1, priority=0, t_submit=0.0)
    sched.enqueue(req, now_s=0.0)
    d0 = req.deadline_s
    assert d0 == pytest.approx(0.25)
    got = sched.pop_admissible(0, engine=_StubEngine(), now_s=0.01)
    assert got is not None
    assert sched.handle_preempted(req, 0, now_s=0.05) == "requeue"
    assert req.deadline_s == pytest.approx(d0)


def test_slo_run_sheds_under_overload(toy_cfg, params):
    """End-to-end: an overloaded replay under the slo policy sheds
    hopeless requests (counted per-reason) instead of serving them late;
    fifo on the same trace serves everything late instead."""
    classes = (SLOClass("interactive", 0, ttft_slo_s=0.08, e2e_slo_s=2.0,
                        share=0.5, max_new=4),
               SLOClass("bulk", 2, ttft_slo_s=0.3, e2e_slo_s=8.0,
                        share=0.5, max_new=8))
    trace = synthesize(TraceConfig(seed=1, duration_s=1.0, rate_rps=25.0,
                                   burstiness=0.5), classes)
    cost = CostModel(step_overhead_s=0.01, prefill_chunk_s=0.02,
                     decode_token_s=0.01)
    slo = run_trace(toy_cfg, params, trace, policy="slo", cost=cost,
                    max_seqs=2)
    fifo = run_trace(toy_cfg, params, trace, policy="fifo", cost=cost,
                     max_seqs=2)
    assert slo.summary["serving"]["drops_slo_shed"] > 0
    assert slo.summary["serving"]["drops_slo_shed"] == \
        slo.summary["dropped"] == len(slo.dropped)
    assert fifo.summary["serving"]["drops_slo_shed"] == 0
    # shedding is what buys the attainment: the slo policy completes its
    # survivors inside the SLO at a higher rate than fifo completes at all
    s_i = slo.summary["classes"]["interactive"]
    f_i = fifo.summary["classes"]["interactive"]
    assert s_i["slo_attainment"] > f_i["slo_attainment"]


# ---------------------------------------------------------------------- CLI
def test_cli_smoke_and_validation(tmp_path):
    from repro.loadgen.__main__ import main
    from repro.obs.validate import validate_loadgen_jsonl
    out = tmp_path / "run.jsonl"
    rc = main(["--trace", "synthetic", "--seed", "0", "--quick",
               "--policy", "slo", "--jsonl", str(out), "--quiet",
               "--save-trace", str(tmp_path / "trace.jsonl")])
    assert rc == 0
    assert validate_loadgen_jsonl(str(out), min_requests=5) == []
    reloaded = load_trace(str(tmp_path / "trace.jsonl"))
    assert len(reloaded.requests) > 0
