"""Training-engine tests: scan-pipeline parity vs the seed loop-trainer,
fused-vs-ref a3po gradients, single host transfer, microbatch accumulation,
sharded state placement, and the unified alpha dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.core.algorithms import get_algorithm
from repro.core.a3po import (
    alpha_from_staleness,
    compute_prox_logp_approximation,
    staleness,
)
from repro.core.advantages import group_normalized_advantages
from repro.core.objective import (
    coupled_ppo_loss,
    decoupled_ppo_loss,
    fused_a3po_loss,
    policy_objective,
    resolve_alpha,
)
from repro.training.optimizer import adam_update
from repro.training.trainer import (
    TrainBatch,
    Trainer,
    TrainState,
    _score_tokens,
    recompute_prox_logp,
)

B, T = 8, 12


@pytest.fixture(scope="module")
def toy():
    return dataclasses.replace(get_config("toy-2m"), dtype="float32")


@pytest.fixture(scope="module")
def rl():
    return RLConfig(group_size=4, num_minibatches=2, learning_rate=3e-4)


def make_batch(per_token_versions: bool, seed: int = 0) -> TrainBatch:
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 5)
    tokens = jax.random.randint(ks[0], (B, T), 4, 60)
    mask = (jnp.arange(T - 1)[None, :] >= 4).astype(jnp.float32) \
        * (jax.random.uniform(ks[1], (B, T - 1)) > 0.2)
    behav = -jax.random.uniform(ks[2], (B, T - 1)) * 2 * mask
    if per_token_versions:
        versions = jax.random.randint(ks[3], (B, T - 1), 0, 4)
    else:
        versions = jax.random.randint(ks[3], (B,), 0, 4)
    rewards = jax.random.uniform(ks[4], (B,)).astype(jnp.float32)
    return TrainBatch(tokens=tokens, response_mask=mask, behav_logp=behav,
                      versions=versions, rewards=rewards)


def reference_loop_step(cfg, rl, method, state, batch):
    """The seed PR-1 loop trainer, reimplemented over the modular jnp
    losses (no fused kernel, Python minibatch loop, host-side metric
    aggregation) — the parity oracle for the compiled scan engine."""
    adv_seq = group_normalized_advantages(batch.rewards, rl.group_size)
    advantages = adv_seq[:, None] * batch.response_mask
    prox_full = (recompute_prox_logp(state.params, cfg, batch.tokens)
                 if method == "recompute" else None)
    params, opt = state.params, state.opt
    nmb = min(rl.num_minibatches, B)
    mb = B // nmb
    mets = []
    for i in range(nmb):
        sl = slice(i * mb, (i + 1) * mb)

        def loss_fn(p):
            logp, entropy, aux = _score_tokens(p, cfg, batch.tokens[sl])
            behav, adv = batch.behav_logp[sl], advantages[sl]
            mask = batch.response_mask[sl]
            if method == "sync":
                loss, m = coupled_ppo_loss(logp, behav, adv, mask, rl,
                                           entropy)
            elif method == "recompute":
                loss, m = decoupled_ppo_loss(logp, behav, prox_full[sl],
                                             adv, mask, rl, entropy)
            else:
                prox = compute_prox_logp_approximation(
                    behav, logp, batch.versions[sl], state.version, rl)
                loss, m = decoupled_ppo_loss(logp, behav, prox, adv, mask,
                                             rl, entropy)
            return loss + aux, m

        (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, gnorm = adam_update(grads, opt, params, rl)
        mets.append({k: float(v)
                     for k, v in dict(m, loss=loss, grad_norm=gnorm).items()})
    out = {k: float(np.mean([m[k] for m in mets])) for k in mets[0]}
    out["iw_max"] = float(np.max([m["iw_max"] for m in mets]))
    out["iw_min"] = float(np.min([m["iw_min"] for m in mets]))
    out["clipped_tokens"] = float(np.sum([m["clipped_tokens"]
                                          for m in mets]))
    d = state.version - batch.versions
    if batch.versions.ndim == 2:
        msum = float(jnp.sum(batch.response_mask))
        out["staleness_mean"] = float(
            jnp.sum(d * batch.response_mask) / max(msum, 1.0))
    else:
        out["staleness_mean"] = float(d.mean())
    out["reward_mean"] = float(batch.rewards.mean())
    return TrainState(params, opt, state.version + 1), out


PARITY_KEYS = ("loss", "grad_norm", "iw_max", "iw_min", "iw_mean",
               "ratio_mean", "clipped_tokens", "clipped_frac", "entropy",
               "kl", "staleness_mean", "reward_mean")


@pytest.mark.parametrize("method", ["loglinear", "recompute", "sync"])
@pytest.mark.parametrize("per_token", [False, True])
def test_scan_engine_matches_seed_loop(toy, rl, method, per_token):
    """The compiled scan pipeline reproduces the seed loop-trainer's
    metrics and parameters for all three methods, [B] and [B,T] stamps."""
    batch = make_batch(per_token)
    trainer = Trainer(toy, rl, method)
    s_scan = trainer.init_state(jax.random.PRNGKey(3))
    s_ref = trainer.init_state(jax.random.PRNGKey(3))
    # non-zero target version so loglinear sees real staleness
    s_scan = TrainState(s_scan.params, s_scan.opt, jnp.asarray(3, jnp.int32))
    s_ref = TrainState(s_ref.params, s_ref.opt, jnp.asarray(3, jnp.int32))

    s_ref, m_ref = reference_loop_step(toy, rl, method, s_ref, batch)
    s_scan, m_scan = trainer.step(s_scan, batch)

    for k in PARITY_KEYS:
        np.testing.assert_allclose(m_scan[k], m_ref[k], rtol=2e-4,
                                   atol=1e-5, err_msg=k)
    for a, b in zip(jax.tree.leaves(s_scan.params),
                    jax.tree.leaves(s_ref.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=1e-6)


def test_one_host_transfer_per_step(toy, rl, monkeypatch):
    """The scan engine performs exactly one device->host transfer per
    training step (the packed metrics vector)."""
    batch = make_batch(False)
    trainer = Trainer(toy, rl, "loglinear")
    state = trainer.init_state(jax.random.PRNGKey(0))
    trainer.step(state, batch)  # warm the compile cache

    state = trainer.init_state(jax.random.PRNGKey(0))
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: (calls.append(1), real(x))[1])
    _, m = trainer.step(state, batch)
    assert len(calls) == 1
    assert m["host_syncs"] == 1.0
    # recompute pays its explicit prox sync on top
    tr = Trainer(toy, rl, "recompute")
    s2 = tr.init_state(jax.random.PRNGKey(0))
    _, m2 = tr.step(s2, batch)
    assert m2["host_syncs"] == 2.0


def test_fused_gradient_matches_jnp_reference():
    """Fused kernel custom_vjp == jnp decoupled loss gradient to 1e-5."""
    cfg = RLConfig()
    key = jax.random.PRNGKey(0)
    Bt, Tt = 4, 33  # odd T exercises kernel padding
    logp = -jax.random.uniform(key, (Bt, Tt)) * 3
    behav = -jax.random.uniform(jax.random.PRNGKey(1), (Bt, Tt)) * 3
    adv = jax.random.normal(jax.random.PRNGKey(2), (Bt, Tt))
    mask = (jax.random.uniform(jax.random.PRNGKey(3), (Bt, Tt)) > 0.3
            ).astype(jnp.float32)
    versions = jnp.array([0, 1, 2, 5])

    def ref(lp):
        prox = compute_prox_logp_approximation(behav, lp, versions, 5, cfg)
        return decoupled_ppo_loss(lp, behav, prox, adv, mask, cfg)[0]

    def fused(lp):
        alpha = resolve_alpha(cfg, versions=versions, current_version=5)
        return fused_a3po_loss(lp, behav, alpha, adv, mask, cfg)[0]

    np.testing.assert_allclose(float(ref(logp)), float(fused(logp)),
                               rtol=1e-6)
    g_ref = jax.grad(ref)(logp)
    g_fused = jax.grad(fused)(logp)
    np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-7)
    # ... and at staleness 0 (alpha=0, the systematic clip-tie case)
    g0_ref = jax.grad(lambda lp: decoupled_ppo_loss(
        lp, behav, compute_prox_logp_approximation(
            behav, lp, jnp.full((Bt,), 5), 5, cfg),
        adv, mask, cfg)[0])(logp)
    g0_fused = jax.grad(lambda lp: fused_a3po_loss(
        lp, behav, resolve_alpha(cfg, versions=jnp.full((Bt,), 5),
                                 current_version=5),
        adv, mask, cfg)[0])(logp)
    np.testing.assert_allclose(np.asarray(g0_fused), np.asarray(g0_ref),
                               rtol=1e-5, atol=1e-7)


def test_microbatch_accumulation_matches_single(toy, rl):
    """num_microbatches=2 (grad accumulation inside the scan) == 1, also
    with heavily skewed response-token counts across microbatches (the
    accumulation is token-weighted, not an equal average of masked means).
    """
    batch = make_batch(False)
    # skew: rows 0-3 keep ~7 response tokens, rows 4-7 keep exactly one
    skew = np.asarray(batch.response_mask).copy()
    skew[4:, :] = 0.0
    skew[4:, 5] = 1.0
    skewed = dataclasses.replace(
        batch, response_mask=jnp.asarray(skew),
        behav_logp=batch.behav_logp * jnp.asarray(skew))
    for b in (batch, skewed):
        outs = {}
        for nmi in (1, 2):
            tr = Trainer(toy, rl, "loglinear", num_microbatches=nmi)
            s = tr.init_state(jax.random.PRNGKey(1))
            s, m = tr.step(s, b)
            outs[nmi] = (s.params, m)
        np.testing.assert_allclose(outs[1][1]["loss"], outs[2][1]["loss"],
                                   rtol=1e-5, atol=1e-7)
        for x, y in zip(jax.tree.leaves(outs[1][0]),
                        jax.tree.leaves(outs[2][0])):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=5e-3, atol=5e-5)


def test_microbatch_indivisible_raises(toy, rl):
    tr = Trainer(toy, rl, "loglinear", num_microbatches=3)  # mb_size=4
    state = tr.init_state(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="does not divide"):
        tr.step(state, make_batch(False))


def test_donating_trainer_chains_steps(toy, rl):
    """donate_params=True: pure synchronous loop, old state discarded."""
    tr = Trainer(toy, rl, "sync", donate_params=True)
    state = tr.init_state(jax.random.PRNGKey(0))
    for _ in range(2):
        state, m = tr.step(state, make_batch(False))
    assert int(state.version) == 2
    assert np.isfinite(m["loss"])


def test_init_state_places_with_sharding_env(toy, rl):
    from jax.sharding import NamedSharding
    from repro.distributed.sharding import ShardingEnv, use_sharding
    from repro.launch.mesh import make_local_mesh
    from repro.models import model as M

    mesh = make_local_mesh()
    env = ShardingEnv(mesh)
    trainer = Trainer(toy, rl)
    with mesh, use_sharding(env):
        state = trainer.init_state(jax.random.PRNGKey(0))
    psh = M.param_shardings(toy, env)
    for leaf, sh in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(psh)):
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding.spec == sh.spec
    # Adam moments ride the same placements as their params
    for leaf, sh in zip(jax.tree.leaves(state.opt["m"]),
                        jax.tree.leaves(psh)):
        assert leaf.sharding.spec == sh.spec


def test_alpha_kl_adaptive_graceful_and_unified_dispatch():
    """alpha_from_staleness no longer raises on kl_adaptive (falls back to
    the staleness-only inverse schedule); resolve_alpha is the one place
    the KL controller actually dispatches from."""
    cfg = RLConfig(alpha_schedule="kl_adaptive")
    d = jnp.array([0.0, 1.0, 2.0, 4.0])
    np.testing.assert_allclose(alpha_from_staleness(d, cfg),
                               [0.0, 1.0, 0.5, 0.25])
    key = jax.random.PRNGKey(0)
    logp = -jax.random.uniform(key, (4, 8)) * 2
    behav = logp + 0.1
    mask = jnp.ones((4, 8))
    a = resolve_alpha(cfg, logp=logp, behav_logp=behav, mask=mask)
    assert a.shape == (4, 1)
    assert bool(jnp.all((a >= 0) & (a <= 1)))
    # staleness schedules still need stamps through the same entry point
    a2 = resolve_alpha(RLConfig(), versions=jnp.array([1, 1, 3, 3]),
                       current_version=3)
    np.testing.assert_allclose(a2, [0.5, 0.5, 0.0, 0.0])
    loss, m = policy_objective(get_algorithm("a3po"), logp, behav,
                               jnp.ones((4, 8)), mask, cfg)
    assert np.isfinite(float(loss))


def test_policy_objective_loglinear_string_still_warns():
    """The stringly-typed shim stays: 'loglinear' resolves through the
    registry with a DeprecationWarning and matches the Algorithm call."""
    key = jax.random.PRNGKey(1)
    logp = -jax.random.uniform(key, (4, 8)) * 2
    behav = logp + 0.1
    adv, mask = jnp.ones((4, 8)), jnp.ones((4, 8))
    cfg = RLConfig()
    kw = dict(versions=jnp.array([0, 1, 2, 3]), current_version=3)
    with pytest.warns(DeprecationWarning, match="stringly-typed"):
        l_str, _ = policy_objective("loglinear", logp, behav, adv, mask,
                                    cfg, **kw)
    l_algo, _ = policy_objective(get_algorithm("a3po"), logp, behav, adv,
                                 mask, cfg, **kw)
    np.testing.assert_allclose(float(l_str), float(l_algo), rtol=1e-7)


def test_trainer_step_kl_adaptive_end_to_end(toy):
    rl = RLConfig(group_size=4, num_minibatches=2,
                  alpha_schedule="kl_adaptive")
    tr = Trainer(toy, rl, "loglinear")
    state = tr.init_state(jax.random.PRNGKey(0))
    state, m = tr.step(state, make_batch(True))
    assert np.isfinite(m["loss"])
    assert int(state.version) == 1


def test_assemble_vectorized_matches_loop_semantics(toy, rl):
    """Vectorized scatter == the seed per-sequence loop, [B] and [B,T]."""
    from repro.rollout.engine import RolloutBatch
    from repro.training.trainer import assemble_train_batch
    rng = np.random.default_rng(0)

    def mk(Bp, P, N, version, per_token):
        lengths = rng.integers(2, P + 1, Bp)
        gen_mask = (np.arange(N)[None, :]
                    < rng.integers(1, N + 1, Bp)[:, None]).astype(np.float32)
        return RolloutBatch(
            tokens=rng.integers(0, 50, (Bp, P + N)).astype(np.int32),
            prompt_lengths=lengths.astype(np.int32),
            gen_logp=(-rng.uniform(size=(Bp, N)) * gen_mask
                      ).astype(np.float32),
            gen_mask=gen_mask,
            version=version,
            gen_versions=(rng.integers(version, version + 3, (Bp, N))
                          .astype(np.int32) if per_token else None))

    def loop_reference(rollouts):
        tokens = np.concatenate([r.tokens for r in rollouts], axis=0)
        Bt, Tt = tokens.shape
        behav = np.zeros((Bt, Tt - 1), np.float32)
        mask = np.zeros((Bt, Tt - 1), np.float32)
        per_token = any(r.gen_versions is not None for r in rollouts)
        versions = (np.zeros((Bt, Tt - 1), np.int32) if per_token
                    else np.zeros((Bt,), np.int32))
        row = 0
        for r in rollouts:
            N = r.gen_logp.shape[1]
            for b in range(r.batch_size):
                L = int(r.prompt_lengths[b])
                behav[row, L - 1: L - 1 + N] = r.gen_logp[b]
                mask[row, L - 1: L - 1 + N] = r.gen_mask[b]
                if per_token:
                    versions[row, :] = r.version
                    if r.gen_versions is not None:
                        versions[row, L - 1: L - 1 + N] = np.where(
                            r.gen_mask[b] > 0, r.gen_versions[b], r.version)
                else:
                    versions[row] = r.version
                row += 1
        return behav, mask, versions

    for per_token in (False, True):
        rollouts = [mk(3, 6, 4, 1, per_token), mk(2, 6, 4, 2, False)]
        rewards = np.ones(5, np.float32)
        tb = assemble_train_batch(rollouts, rewards)
        behav, mask, versions = loop_reference(rollouts)
        np.testing.assert_array_equal(np.asarray(tb.behav_logp), behav)
        np.testing.assert_array_equal(np.asarray(tb.response_mask), mask)
        np.testing.assert_array_equal(np.asarray(tb.versions), versions)
