"""Benchmark harness entry point — one bench per paper table/figure.

  fig1    -> bench_prox_time     (prox logprob computation time)
  table1  -> bench_training      (end-to-end training: time + reward,
                                  figs 2-6 statistics)
  roofline-> bench_roofline      (dry-run derived roofline per arch x mesh)
  kernels -> bench_kernels       (hot-spot microbenches)
  prefix  -> bench_prefix_cache  (radix prefix cache: shared prefills for
                                  GRPO-style grouped prompts)

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import CsvOut


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   choices=["fig1", "table1", "roofline", "kernels",
                            "prefix"])
    p.add_argument("--steps", type=int, default=30,
                   help="RL steps for the training bench")
    args = p.parse_args()

    csv = CsvOut()
    csv.header()
    failures = []

    def section(name, fn):
        if args.only and args.only != name:
            return
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            import traceback
            traceback.print_exc()

    from benchmarks import (bench_kernels, bench_prefix_cache,
                            bench_prox_time, bench_roofline, bench_training)
    section("fig1", lambda: bench_prox_time.run(csv))
    section("kernels", lambda: bench_kernels.run(csv))
    section("roofline", lambda: bench_roofline.run(csv))
    section("prefix", lambda: bench_prefix_cache.run(csv))
    section("table1", lambda: bench_training.run(csv, num_steps=args.steps))

    if failures:
        print(f"# FAILED sections: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmark sections completed")


if __name__ == "__main__":
    main()
