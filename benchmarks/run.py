"""Benchmark harness entry point — one bench per paper table/figure.

  fig1    -> bench_prox_time     (prox logprob computation time)
  table1  -> bench_training      (end-to-end training: time + reward,
                                  figs 2-6 statistics)
  roofline-> bench_roofline      (dry-run derived roofline per arch x mesh)
  kernels -> bench_kernels       (hot-spot microbenches)
  prefix  -> bench_prefix_cache  (radix prefix cache: shared prefills for
                                  GRPO-style grouped prompts)
  decode  -> bench_decode        (serving: per-token vs fused-horizon
                                  decode tokens/sec + host syncs)
  prefill -> bench_prefill       (serving: inline dense prefill vs the
                                  chunked prefill lane — TTFT + tok/s)
  load    -> bench_load          (serving: SLO-aware scheduling vs FIFO
                                  under trace-driven overload)
  load_multiarch -> bench_load --multiarch (serving: one overload trace
                                  against dense/SSM/hybrid towers with
                                  per-arch fitted cost models)
  resilience -> bench_resilience (fault tolerance: worker-crash MTTR,
                                  steps lost vs ckpt_every, checkpoint
                                  save/restore latency, publish retries)

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from benchmarks.common import CsvOut

PHASE_JSON = (pathlib.Path(__file__).resolve().parent.parent
              / "experiments" / "bench_phases.json")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   choices=["fig1", "table1", "roofline", "kernels",
                            "prefix", "decode", "prefill", "load",
                            "load_multiarch", "resilience"])
    p.add_argument("--steps", type=int, default=30,
                   help="RL steps for the training bench")
    p.add_argument("--quick", action="store_true",
                   help="CI smoke mode: tiny step counts; skips the "
                        "kernels/roofline/prefix sections unless --only "
                        "is given")
    p.add_argument("--phase-json", default=None, metavar="FILE",
                   help="attach the span tracer and write a per-phase "
                        "(rollout/prefill/decode/train/publish) breakdown "
                        "JSON; defaults on under --quick")
    p.add_argument("--trace", default=None, metavar="FILE",
                   help="also export the full Chrome trace.json")
    args = p.parse_args()
    steps = min(args.steps, 3) if args.quick else args.steps
    sft_steps = 10 if args.quick else 150

    phase_json = args.phase_json or (str(PHASE_JSON) if args.quick else None)
    tracer = None
    if phase_json or args.trace:
        from repro.obs.tracing import SpanTracer, install_tracer
        tracer = install_tracer(SpanTracer())

    csv = CsvOut()
    csv.header()
    failures = []

    def section(name, fn, skip_quick=False):
        if args.only and args.only != name:
            return
        if args.quick and skip_quick and not args.only:
            return
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            import traceback
            traceback.print_exc()

    from benchmarks import (bench_decode, bench_kernels, bench_load,
                            bench_prefill, bench_prefix_cache,
                            bench_prox_time, bench_resilience,
                            bench_roofline, bench_training)
    section("fig1", lambda: bench_prox_time.run(csv))
    section("kernels", lambda: bench_kernels.run(csv), skip_quick=True)
    section("roofline", lambda: bench_roofline.run(csv), skip_quick=True)
    section("prefix", lambda: bench_prefix_cache.run(csv), skip_quick=True)
    # quick mode keeps a decode row (tiny horizon sweep) but never
    # overwrites the committed experiment JSON (PR 3 convention)
    section("decode", lambda: bench_decode.run(csv, quick=args.quick,
                                               save_json=not args.quick))
    section("prefill", lambda: bench_prefill.run(csv, quick=args.quick,
                                                 save_json=not args.quick))
    section("load", lambda: bench_load.run(csv, quick=args.quick,
                                           save_json=not args.quick))
    section("load_multiarch",
            lambda: bench_load.run_multiarch(csv, quick=args.quick,
                                             save_json=not args.quick))
    section("resilience",
            lambda: bench_resilience.run(csv, quick=args.quick,
                                         save_json=not args.quick))
    section("table1", lambda: bench_training.run(
        csv, num_steps=steps, sft_steps=sft_steps,
        save_json=not args.quick))

    if tracer is not None:
        from repro.obs.tracing import phase_breakdown
        phases = phase_breakdown(tracer.events())
        if args.trace:
            tracer.export(args.trace)
            print(f"# trace -> {args.trace}", flush=True)
        if phase_json:
            pathlib.Path(phase_json).parent.mkdir(parents=True,
                                                  exist_ok=True)
            with open(phase_json, "w") as f:
                json.dump({"phases": phases,
                           "quick": args.quick,
                           "sections": args.only or "default"}, f, indent=2)
            print(f"# phase breakdown -> {phase_json}", flush=True)
        for name, st in sorted(phases.items()):
            print(f"# phase {name}: {st['total_s']:.3f}s over "
                  f"{st['count']} spans (mean {st['mean_ms']:.2f}ms)",
                  flush=True)

    if failures:
        print(f"# FAILED sections: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmark sections completed")


if __name__ == "__main__":
    main()
