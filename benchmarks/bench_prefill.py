"""Prefill-path latency: inline dense whole-sequence prefill vs the
chunked, budgeted prefill lane.

A mixed long/short workload is served through the full control plane
(admission queue -> prefill lane -> decode lane). The dense baseline
prefills each admitted prompt inline and whole, so a long prompt stalls
the step — every co-admitted short request's first token waits behind
it. The chunked lane streams prompts through fixed-shape, packed chunk
launches under a per-step budget, so shorts prefill (and start decoding)
between a long prompt's chunks.

Reported per mode: time-to-first-token p50/p99 over all requests
(submit -> first sampled token, wall clock), aggregate generated
tokens/sec, and the prefill compile count (bucket-ladder effectiveness —
stays ~#buckets, not ~#distinct prompt lengths). The committed
``experiments/prefill_pipeline.json`` records the full run; the headline
is the TTFT p99 ratio at equal aggregate throughput.

Run directly (``python -m benchmarks.bench_prefill [--quick]``) or as
the ``prefill`` section of ``benchmarks.run``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import CsvOut, toy_config
from repro.async_rl.weights import WeightStore
from repro.models import model as M
from repro.rollout.continuous import ContinuousBatchingEngine
from repro.serving import (
    AdmissionScheduler,
    SchedulerConfig,
    ServingControlPlane,
)

OUT_JSON = (pathlib.Path(__file__).resolve().parent.parent / "experiments"
            / "prefill_pipeline.json")


def _workload(cfg, *, n_short: int, n_long: int, short_len: int,
              long_len: int, seed: int = 0) -> List[np.ndarray]:
    """Interleaved long/short prompt mix (longs spread through the queue,
    as in a serving trace — not front-loaded)."""
    rng = np.random.default_rng(seed)
    shorts = [rng.integers(4, cfg.vocab_size,
                           size=int(rng.integers(short_len // 2,
                                                 short_len + 1))
                           ).astype(np.int32) for _ in range(n_short)]
    longs = [rng.integers(4, cfg.vocab_size, size=long_len).astype(np.int32)
             for _ in range(n_long)]
    prompts = list(shorts)
    stride = max(len(prompts) // (n_long + 1), 1)
    for i, p in enumerate(longs):
        prompts.insert(stride * (i + 1), p)
    return prompts


def _serve_run(cfg, params, *, mode: str, prompts: List[np.ndarray],
               max_new: int, prefill_chunk: int, prefill_budget: int,
               max_seqs: int) -> Dict[str, object]:
    longest = max(len(p) for p in prompts)
    mb = -(-(longest + max_new) // 8) + 1
    eng = ContinuousBatchingEngine(
        cfg, max_seqs=max_seqs, block_size=8,
        n_blocks=max_seqs * mb + 1, max_blocks_per_seq=mb, greedy=True,
        prefill_mode=mode, prefill_chunk=prefill_chunk)
    cp = ServingControlPlane(
        eng, WeightStore(params, 0),
        AdmissionScheduler(SchedulerConfig(d_max=1_000)),
        use_prefix_cache=False,  # random prompts: isolate the prefill path
        prefill_budget=prefill_budget)
    key = jax.random.PRNGKey(1)
    t0 = time.perf_counter()
    for p in prompts:  # t_submit stamps here: TTFT includes queueing
        cp.submit(p, max_new=max_new)
    finished = []
    while len(finished) < len(prompts):
        key, sub = jax.random.split(key)
        finished.extend(cp.step(sub))
    jax.block_until_ready(eng.state.pool_k)
    dt = time.perf_counter() - t0
    ttfts = np.array([r.t_first_token - r.t_submit for r in finished])
    tokens = sum(len(r.generated) for r in finished)
    return dict(seconds=dt, tokens=tokens, tokens_per_s=tokens / dt,
                ttft_p50_ms=float(np.percentile(ttfts, 50)) * 1e3,
                ttft_p99_ms=float(np.percentile(ttfts, 99)) * 1e3,
                ttft_max_ms=float(ttfts.max()) * 1e3,
                prefill_compiles=eng.prefill_compiles,
                prefill_launches=eng.prefill_launches)


def run(csv: CsvOut, *, quick: bool = False, save_json: bool = True) -> None:
    cfg = toy_config()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if quick:
        wl = dict(n_short=4, n_long=1, short_len=12, long_len=48)
        max_new, max_seqs, chunk, repeats = 4, 4, 16, 1
    else:
        wl = dict(n_short=12, n_long=2, short_len=16, long_len=96)
        max_new, max_seqs, chunk, repeats = 16, 4, 48, 3
    prompts = _workload(cfg, **wl)
    kw = dict(prompts=prompts, max_new=max_new, prefill_chunk=chunk,
              prefill_budget=2, max_seqs=max_seqs)
    modes = ("dense", "chunked")
    for m in modes:  # warmup: compile every bucket outside the timed runs
        _serve_run(cfg, params, mode=m, **kw)
    # interleaved best-of-N (min wall time): noisy-neighbour CPU load hits
    # both modes equally instead of biasing one window
    best: Dict[str, Dict[str, object]] = {}
    for _ in range(repeats):
        for m in modes:
            r = _serve_run(cfg, params, mode=m, **kw)
            if m not in best or r["seconds"] < best[m]["seconds"]:
                best[m] = r
    rows = []
    for m in modes:
        r = dict(mode=m, **best[m])
        r["ttft_p99_vs_dense"] = (best[m]["ttft_p99_ms"]
                                  / best["dense"]["ttft_p99_ms"])
        rows.append(r)
        csv.add(f"prefill/{m}", r["seconds"] / r["tokens"],
                derived=f"tok/s={r['tokens_per_s']:.0f} "
                        f"ttft_p50={r['ttft_p50_ms']:.1f}ms "
                        f"p99={r['ttft_p99_ms']:.1f}ms "
                        f"compiles={r['prefill_compiles']}")
    if save_json:
        OUT_JSON.write_text(json.dumps(
            {"bench": "prefill_pipeline", "max_new": max_new,
             "max_seqs": max_seqs, "prefill_chunk": chunk,
             "prefill_budget": 2, "workload": wl, "rows": rows},
            indent=2) + "\n")
        print(f"# wrote {OUT_JSON}")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: tiny workload, 1 repeat; does not "
                        "overwrite the committed JSON")
    args = p.parse_args()
    csv = CsvOut()
    csv.header()
    run(csv, quick=args.quick, save_json=not args.quick)


if __name__ == "__main__":
    main()
