"""Kernel micro-benchmarks (CPU reference path timings + shape sweeps).

On this container kernels execute via the jnp reference (Pallas interpret
mode is a correctness tool, not a performance path); these numbers anchor
the relative cost of the logprob hot spot the paper's recompute pays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import CsvOut, time_fn
from repro.kernels.logprob.ref import token_logprob_entropy_ref


def run(csv: CsvOut) -> None:
    key = jax.random.PRNGKey(0)
    for (T, d, V) in [(512, 256, 1024), (2048, 512, 8192),
                      (2048, 512, 32768)]:
        h = jax.random.normal(key, (T, d), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (d, V),
                              jnp.float32) * 0.05
        t = jax.random.randint(jax.random.PRNGKey(2), (T,), 0, V)
        f = jax.jit(token_logprob_entropy_ref)
        sec, _ = time_fn(f, h, w, t)
        flops = 2 * T * d * V
        csv.add(f"kernels/logprob_ref/T{T}_d{d}_V{V}", sec,
                f"{flops / sec / 1e9:.1f} GFLOP/s")

    # SSD: chunked matmul form vs naive sequential scan (the TPU adaptation
    # argument: same math, matmul-dominated)
    from repro.models.ssm import ssd_chunked
    from repro.kernels.ssd.ref import ssd_sequential_ref
    B, S, nh, hd, ds = 2, 512, 8, 64, 64
    x = jax.random.normal(key, (B, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3),
                                           (B, S, nh)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, nh))
    b = jax.random.normal(jax.random.PRNGKey(4), (B, S, ds)) * 0.3
    c = jax.random.normal(jax.random.PRNGKey(5), (B, S, ds)) * 0.3
    f_chunk = jax.jit(lambda *a: ssd_chunked(*a, 64))
    f_seq = jax.jit(ssd_sequential_ref)
    sec_c, _ = time_fn(f_chunk, x, dt, a_log, b, c)
    sec_s, _ = time_fn(f_seq, x, dt, a_log, b, c)
    csv.add("kernels/ssd_chunked", sec_c,
            f"vs sequential {sec_s / sec_c:.1f}x faster (even on CPU)")
    csv.add("kernels/ssd_sequential", sec_s, "")


if __name__ == "__main__":
    c = CsvOut()
    c.header()
    run(c)
