"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (produced by launch/dryrun.py) and prints
per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio, and bytes/device.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import CsvOut

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(mesh: str = "16x16") -> List[Dict]:
    """Baseline records only (variant files carry a tag suffix)."""
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        stem = os.path.splitext(os.path.basename(path))[0]
        if (r.get("mesh") == mesh
                and stem == f"{r['arch']}_{r['shape']}_{r['mesh']}"):
            recs.append(r)
    return recs


def format_table(recs: List[Dict]) -> str:
    """memory_s is the trip-corrected op-boundary traffic (an UPPER bound:
    the CPU-backend HLO fuses less than TPU). mem_lb_s is the buffer-
    assignment lower bound (every allocated byte touched once)."""
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'mem_lb_s':>9s} {'coll_s':>10s} {'dominant':>11s} "
           f"{'useful%':>8s} {'temp_GiB':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        t = r["roofline"]
        useful = r.get("useful_flops_ratio")
        useful_s = f"{useful * 100:.0f}" if useful else "-"
        mem = r["memory"]
        temp = mem.get("temp_size_in_bytes", 0) / 2**30
        lb_bytes = (mem.get("argument_size_in_bytes", 0)
                    + mem.get("output_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0))
        lb_s = lb_bytes / 819e9
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {t['compute_s']:10.3e} "
            f"{t['memory_s']:10.3e} {lb_s:9.3e} {t['collective_s']:10.3e} "
            f"{t['dominant'].replace('_s',''):>11s} {useful_s:>8s} "
            f"{temp:9.2f}")
    return "\n".join(lines)


def run(csv: CsvOut) -> None:
    for mesh in ("16x16", "2x16x16"):
        recs = load_records(mesh)
        if not recs:
            continue
        print(f"\n=== Roofline ({mesh}, {len(recs)} combos) ===")
        print(format_table(recs))
        worst = min(
            (r for r in recs if r.get("useful_flops_ratio")),
            key=lambda r: r["useful_flops_ratio"])
        dom_counts: Dict[str, int] = {}
        for r in recs:
            dom_counts[r["roofline"]["dominant"]] = dom_counts.get(
                r["roofline"]["dominant"], 0) + 1
        csv.add(f"roofline/{mesh}/combos", 0.0,
                f"n={len(recs)} dominant={dom_counts} "
                f"worst_useful={worst['arch']}x{worst['shape']}="
                f"{worst['useful_flops_ratio']*100:.0f}%")
        for r in recs:
            t = r["roofline"]
            csv.add(f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                    max(t["compute_s"], t["memory_s"], t["collective_s"]),
                    f"dominant={t['dominant']}")


if __name__ == "__main__":
    c = CsvOut()
    c.header()
    run(c)
