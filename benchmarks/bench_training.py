"""Paper Table 1 + Figs 2-6: end-to-end RL training comparison.

Runs every benchmarked Algorithm-registry entry (the paper's sync GRPO /
recompute / a3po plus the beyond-paper asympo and grpo_mu) on the
synthetic arithmetic task with an SFT-warmed toy model, at matched
training epochs, and reports:

  * final train/eval reward            (Table 1, Fig 2-3)
  * wall-clock per step + prox time    (Table 1, Fig 1)
  * schedule-model async speedup       (Table 1: on one CPU core rollout and
    training cannot physically overlap, so async wall time is modeled as
    sum(max(rollout_t, train_t)) + sync as sum(rollout_t + train_t) from the
    *measured* per-step times — the standard dry-run timing model)
  * entropy decay, IW max/min, clipped tokens  (Figs 4-6)

Results are also dumped to experiments/training_<method>.json for
EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import CsvOut, toy_config
from repro.configs.base import RLConfig
from repro.core.algorithms import BUILTINS, get_algorithm
from repro.async_rl.orchestrator import simulate_async
from repro.data.tasks import ArithmeticTask
from repro.rollout.engine import RolloutEngine
from repro.training.optimizer import adam_init
from repro.training.trainer import TrainState, Trainer, sft_update

EXP_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")


def sft_warmup(cfg, task: ArithmeticTask, steps: int = 150,
               batch: int = 32, total_len: int = 14, lr: float = 3e-3,
               seed: int = 0):
    """Supervised warmup so RL starts from a non-degenerate base policy."""
    params = None
    trainer = Trainer(cfg, RLConfig())
    state = trainer.init_state(jax.random.PRNGKey(seed))
    params, opt = state.params, state.opt
    loss = None
    for i in range(steps):
        toks, mask = task.sft_batch(batch, total_len)
        params, opt, loss = sft_update(cfg, params, opt, toks, mask, lr=lr)
    return params, float(loss)


def eval_reward(cfg, params, task: ArithmeticTask, n: int = 64,
                max_new: int = 6, seed: int = 123) -> float:
    """Greedy decoding on held-out prompts (paper Fig. 3)."""
    engine = RolloutEngine(cfg, RLConfig(), max_new_tokens=max_new)
    eval_task = ArithmeticTask(task.max_operand, task.n_terms,
                               task.prompt_len, seed=seed)
    b = eval_task.sample(n)
    rb = engine.generate(params, b.prompts, b.prompt_lengths,
                         jax.random.PRNGKey(0), greedy=True)
    return float(eval_task.rewards(engine.completions(rb),
                                   b.answers).mean())


def run(csv: CsvOut, num_steps: int = 30, seed: int = 0,
        sft_steps: int = 150, save_json: bool = True) -> Dict[str, dict]:
    """``save_json=False`` (CI --quick smoke) skips the
    experiments/training_<algo>.json dumps so throwaway short runs never
    clobber the committed paper-figure data."""
    cfg = toy_config("toy-2m")
    task = ArithmeticTask(max_operand=9, n_terms=2, prompt_len=8, seed=seed)
    rl = RLConfig(group_size=4, num_minibatches=2, learning_rate=2e-4,
                  max_staleness=4)

    base_params, sft_loss = sft_warmup(cfg, task, steps=sft_steps)
    base_eval = eval_reward(cfg, base_params, task)
    csv.add("table1/sft_base_eval_reward", 0.0,
            f"reward={base_eval:.3f} sft_loss={sft_loss:.3f}")

    results: Dict[str, dict] = {}
    # one row per built-in Algorithm-registry entry (incl. the
    # beyond-paper asympo / grpo_mu plugins)
    for name in BUILTINS:
        algo = get_algorithm(name)
        staleness = 0 if algo.on_policy else 2
        state = TrainState(base_params, adam_init(base_params),
                           jax.numpy.zeros((), jax.numpy.int32))
        state, recs = simulate_async(
            cfg, rl, task, algo, num_steps=num_steps, n_prompts=8,
            max_new_tokens=6, staleness=staleness, seed=seed,
            init_state=state)
        final_eval = eval_reward(cfg, state.params, task)

        rollout_t = np.array([r.rollout_time_s for r in recs[2:]])
        train_t = np.array([r.train_time_s for r in recs[2:]])
        prox_t = np.array([r.prox_time_s for r in recs[2:]])
        train_tok = np.array([r.train_tokens for r in recs[2:]])
        host_syncs = np.array([r.host_syncs for r in recs[2:]])
        # schedule model (measured components):
        seq_time = float(np.sum(rollout_t + train_t))
        overlap_time = float(np.sum(np.maximum(rollout_t, train_t)))

        res = {
            "algo": name,
            "staleness": staleness,
            "steps": num_steps,
            "final_train_reward": float(np.mean(
                [r.reward for r in recs[-5:]])),
            "final_eval_reward": final_eval,
            "base_eval_reward": base_eval,
            "mean_step_time_s": float(np.mean(rollout_t + train_t)),
            "mean_train_time_s": float(np.mean(train_t)),
            "mean_prox_time_s": float(np.mean(prox_t)),
            # training-engine throughput: response tokens updated per
            # second of trainer wall-clock, and device->host transfers per
            # step (1 for the scan engine; 2 for the recompute baseline)
            "train_tokens_per_s": float(np.sum(train_tok)
                                        / max(np.sum(train_t), 1e-9)),
            "host_syncs_per_step": float(np.mean(host_syncs)),
            "seq_wall_time_s": seq_time,
            "overlap_wall_time_s": overlap_time,
            "entropy": [r.entropy for r in recs],
            "iw_max": [r.iw_max for r in recs],
            "iw_min": [r.iw_min for r in recs],
            "clipped_tokens": [r.clipped_tokens for r in recs],
            "reward_curve": [r.reward for r in recs],
        }
        results[name] = res
        if save_json:
            os.makedirs(EXP_DIR, exist_ok=True)
            with open(os.path.join(EXP_DIR, f"training_{name}.json"),
                      "w") as f:
                json.dump(res, f, indent=2)
        csv.add(f"table1/{name}/step_time", res["mean_step_time_s"],
                f"eval_reward={final_eval:.3f} "
                f"prox_t={res['mean_prox_time_s']*1e3:.2f}ms "
                f"clip_tok={np.mean(res['clipped_tokens']):.1f}")
        csv.add(f"table1/{name}/train_throughput",
                res["mean_train_time_s"],
                f"tokens_per_s={res['train_tokens_per_s']:.0f} "
                f"host_syncs_per_step={res['host_syncs_per_step']:.1f}")

    # paper-style derived comparisons (a3po == the paper's loglinear)
    if all(m in results for m in ("sync", "recompute", "a3po")):
        t_sync = results["sync"]["seq_wall_time_s"]
        # async methods overlap rollout & training (schedule model)
        t_rec = results["recompute"]["overlap_wall_time_s"]
        t_ll = results["a3po"]["overlap_wall_time_s"]
        csv.add("table1/speedup_loglinear_vs_sync", 0.0,
                f"{t_sync / t_ll:.2f}x (paper: 1.5-1.8x)")
        csv.add("table1/speedup_loglinear_vs_recompute", 0.0,
                f"{t_rec / t_ll:.2f}x (paper: 1.1-1.2x)")
        csv.add("fig5/iw_max", 0.0,
                "loglinear={:.2f} recompute={:.2f} (loglinear more "
                "controlled)".format(
                    float(np.max(results["a3po"]["iw_max"])),
                    float(np.max(results["recompute"]["iw_max"]))))
        csv.add("fig6/clipped_tokens_mean", 0.0,
                "loglinear={:.1f} recompute={:.1f} sync={:.1f}".format(
                    *[float(np.mean(results[m]["clipped_tokens"]))
                      for m in ("a3po", "recompute", "sync")]))
    return results


if __name__ == "__main__":
    c = CsvOut()
    c.header()
    run(c)
