"""Decode-path throughput: per-token steps vs the fused decode horizon.

Measures tokens/sec and host-syncs-per-token of the continuous-batching
engine across decode horizons H (1 = the per-token baseline: sampled
tokens drained to the host every step) and slot counts. The fused path
(`step_horizon`) runs H tokens per compiled launch and drains once, so
the ratio at H=32 / max_seqs=8 is the headline serving speedup; the
committed `experiments/decode_horizon.json` records it.

Run directly (``python -m benchmarks.bench_decode [--quick]``) or as the
``decode`` section of ``benchmarks.run``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import Tuple

import jax
import numpy as np

from benchmarks.common import CsvOut, toy_config
from repro.configs.base import RLConfig
from repro.models import model as M
from repro.rollout.continuous import ContinuousBatchingEngine

OUT_JSON = (pathlib.Path(__file__).resolve().parent.parent / "experiments"
            / "decode_horizon.json")


def _decode_run(cfg, params, *, horizon: int, max_seqs: int, max_new: int,
                seed: int = 0) -> Tuple[float, int, int, int]:
    """Prefill ``max_seqs`` requests, then time the decode loop only.

    Returns (seconds, tokens, host_syncs, decode_launches).
    """
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(4, cfg.vocab_size, size=12).astype(np.int32)
               for _ in range(max_seqs)]
    srv = ContinuousBatchingEngine(
        cfg, max_seqs=max_seqs, block_size=8,
        n_blocks=max_seqs * ((12 + max_new) // 8 + 2) + 1,
        max_blocks_per_seq=(12 + max_new) // 8 + 2, rl=RLConfig(),
        decode_horizon=horizon)
    for p in prompts:
        srv.submit(p, max_new=max_new)
    srv._admit(params)  # prefill outside the timed region
    key = jax.random.PRNGKey(1)
    done = []
    t0 = time.perf_counter()
    while any(r is not None for r in srv.slots.values()):
        key, sub = jax.random.split(key)
        if horizon > 1:
            done.extend(srv.step_horizon(params, sub))
        else:
            done.extend(srv.step(params, sub))
    jax.block_until_ready(srv.state.pool_k)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in done)
    return dt, tokens, srv.host_syncs, srv.decode_launches


def run(csv: CsvOut, *, quick: bool = False, save_json: bool = True) -> None:
    cfg = toy_config()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    horizons = (1, 8) if quick else (1, 8, 32)
    seq_counts = (4,) if quick else (4, 8)
    max_new = 16 if quick else 64
    rows = []
    repeats = 1 if quick else 5
    configs = [(s, h) for s in seq_counts for h in horizons]
    for s, h in configs:  # warmup: compile + caches
        _decode_run(cfg, params, horizon=h, max_seqs=s, max_new=max_new)
    # interleaved rounds + best-of-N: noisy-neighbour CPU load hits every
    # config equally instead of biasing whichever ran in a bad window
    best = {}
    for _ in range(repeats):
        for s, h in configs:
            r = _decode_run(cfg, params, horizon=h, max_seqs=s,
                            max_new=max_new)
            if (s, h) not in best or r[0] < best[(s, h)][0]:
                best[(s, h)] = r
    for max_seqs in seq_counts:
        base_tps = None
        for horizon in horizons:
            dt, tokens, syncs, launches = best[(max_seqs, horizon)]
            tps = tokens / dt
            if horizon == 1:
                base_tps = tps
            row = dict(max_seqs=max_seqs, horizon=horizon, tokens=tokens,
                       seconds=dt, tokens_per_s=tps,
                       host_syncs=syncs, decode_launches=launches,
                       host_syncs_per_token=syncs / tokens,
                       host_syncs_per_launch=syncs / launches,
                       speedup_vs_per_token=tps / base_tps)
            rows.append(row)
            csv.add(f"decode/s{max_seqs}_h{horizon}", dt / tokens,
                    derived=f"tok/s={tps:.0f} syncs/tok={syncs/tokens:.3f} "
                            f"speedup={tps / base_tps:.2f}x")
    if save_json:
        OUT_JSON.write_text(json.dumps(
            {"bench": "decode_horizon", "max_new": max_new, "rows": rows},
            indent=2) + "\n")
        print(f"# wrote {OUT_JSON}")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: H in {1,8}, 4 slots, 16 new tokens; "
                        "does not overwrite the committed JSON")
    args = p.parse_args()
    csv = CsvOut()
    csv.header()
    run(csv, quick=args.quick, save_json=not args.quick)


if __name__ == "__main__":
    main()
