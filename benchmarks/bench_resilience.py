"""Resilience bench: MTTR and steps-lost under injected faults.

Four seeded, deterministic measurements against the fault-tolerance
layer (``repro.resilience``):

1. **Worker-crash MTTR** — an ``AsyncOrchestrator`` run with
   ``rollout_crash`` faults injected into the supervised rollout worker.
   MTTR is the crash-to-restart wall time from each ``CrashRecord``
   (backoff included); the trainer pops through ``pop_with_health`` so
   the run finishes every step with zero deadlock and zero steps lost.
2. **Steps lost per trainer crash vs ``ckpt_every``** — ``simulate_async``
   is killed by a ``train_crash`` fault and resumed from the latest
   crash-consistent checkpoint; steps lost = crash step - resume step.
   The resumed run's final params are verified bit-identical to an
   uninterrupted run (the paper-grade resume contract).
3. **Checkpoint save/restore latency** — the full ``TrainState``
   capture (params + Adam state) through ``CheckpointManager``'s atomic
   tmp+fsync+replace path.
4. **Publish-retry recovery** — a ``publish_fail`` burst absorbed by
   ``ResilientPublisher`` backoff while the store keeps the old version.

Headline numbers land in the committed ``experiments/resilience.json``
(``--quick`` never overwrites it).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import tempfile
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import CsvOut, time_fn, toy_config
from repro.async_rl.orchestrator import AsyncOrchestrator, simulate_async
from repro.async_rl.weights import WeightStore
from repro.configs.base import RLConfig
from repro.data.tasks import ArithmeticTask
from repro.models import model as M
from repro.resilience import (
    CheckpointManager,
    FaultPlan,
    InjectedFault,
    ResilienceConfig,
    ResilientPublisher,
)
from repro.training.trainer import Trainer

OUT_JSON = (pathlib.Path(__file__).resolve().parent.parent / "experiments"
            / "resilience.json")


def _task(seed: int = 0) -> ArithmeticTask:
    return ArithmeticTask(max_operand=9, n_terms=2, prompt_len=8, seed=seed)


def _worker_crash_mttr(csv: CsvOut, cfg, rl, *, steps: int,
                       crashes: int) -> Dict[str, object]:
    """Async run that survives ``crashes`` injected rollout-worker deaths."""
    faults = FaultPlan.from_strings([f"rollout_crash@1x{crashes}"])
    res = ResilienceConfig(faults=faults, max_worker_restarts=crashes + 1,
                           pop_deadline_s=120.0)
    orch = AsyncOrchestrator(cfg, rl, _task(), algo="a3po", n_prompts=4,
                             max_new_tokens=6, seed=0, resilience=res)
    state = orch.trainer.init_state(jax.random.PRNGKey(7))
    t0 = time.perf_counter()
    state, recs = orch.run(state, steps)
    wall = time.perf_counter() - t0
    samples = [c.recovery_s for c in orch.worker.crashes
               if c.t_restarted_s >= 0]
    row = {
        "steps": steps,
        "steps_completed": len(recs),
        "crashes": len(orch.worker.crashes),
        "restarts": orch.worker.restarts,
        "steps_lost": steps - len(recs),  # 0: the trainer waits, never dies
        "mttr_mean_s": float(np.mean(samples)) if samples else 0.0,
        "mttr_max_s": float(np.max(samples)) if samples else 0.0,
        "wall_s": wall,
    }
    csv.add("resilience/worker_crash_mttr", row["mttr_mean_s"],
            derived=f"crashes={row['crashes']} restarts={row['restarts']} "
                    f"steps={len(recs)}/{steps} "
                    f"mttr_max={row['mttr_max_s'] * 1e3:.0f}ms")
    return row


def _steps_lost_vs_ckpt_every(csv: CsvOut, cfg, rl, *, num_steps: int,
                              crash_at: int, everies: List[int]
                              ) -> List[Dict[str, object]]:
    """Kill the simulator at ``crash_at``, resume from the latest
    checkpoint, and verify the resumed run is bit-identical to an
    uninterrupted one."""
    base_state, _ = simulate_async(cfg, rl, _task(), "a3po", num_steps,
                                   n_prompts=4, max_new_tokens=6,
                                   staleness=1, seed=0)
    base_leaves = [np.asarray(x) for x in jax.tree.leaves(base_state.params)]

    rows: List[Dict[str, object]] = []
    for every in everies:
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2)
            res = ResilienceConfig(
                checkpointer=mgr, ckpt_every=every,
                faults=FaultPlan.from_strings([f"train_crash@{crash_at}"]))
            try:
                simulate_async(cfg, rl, _task(), "a3po", num_steps,
                               n_prompts=4, max_new_tokens=6, staleness=1,
                               seed=0, resilience=res)
                raise AssertionError("train_crash fault did not fire")
            except InjectedFault:
                pass
            t0 = time.perf_counter()
            info = mgr.restore_latest()
            restore_s = time.perf_counter() - t0
            resume_step = info.step if info is not None else 0
            state, _ = simulate_async(
                cfg, rl, _task(), "a3po", num_steps, n_prompts=4,
                max_new_tokens=6, staleness=1, seed=0,
                resilience=ResilienceConfig(checkpointer=mgr,
                                            ckpt_every=every),
                resume=info)
            leaves = [np.asarray(x) for x in jax.tree.leaves(state.params)]
            bit_exact = all(np.array_equal(a, b)
                            for a, b in zip(base_leaves, leaves))
            row = {"ckpt_every": every, "crash_at": crash_at,
                   "resume_step": resume_step,
                   "steps_lost": crash_at - resume_step,
                   "restore_s": restore_s, "bit_exact_resume": bit_exact}
            rows.append(row)
            csv.add(f"resilience/steps_lost@ckpt_every={every}",
                    restore_s,
                    derived=f"lost={row['steps_lost']} "
                            f"resume_step={resume_step} "
                            f"bit_exact={bit_exact}")
            assert bit_exact, f"resume diverged (ckpt_every={every})"
    return rows


def _ckpt_latency(csv: CsvOut, cfg, rl) -> Dict[str, object]:
    trainer = Trainer(cfg, rl)
    state = trainer.init_state(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        save_s, _ = time_fn(lambda: mgr.save(1, state), warmup=1, iters=3,
                            label="ckpt_save")
        nbytes = os.path.getsize(mgr.path_for(1) + ".npz")
        restore_s, _ = time_fn(mgr.restore_latest, warmup=1, iters=3,
                               label="ckpt_restore")
    row = {"arch": cfg.name, "npz_bytes": nbytes,
           "save_s": save_s, "restore_s": restore_s}
    csv.add("resilience/ckpt_save", save_s,
            derived=f"{nbytes / 1e6:.2f}MB arch={cfg.name}")
    csv.add("resilience/ckpt_restore", restore_s,
            derived=f"{nbytes / 1e6:.2f}MB arch={cfg.name}")
    return row


def _publish_recovery(csv: CsvOut, cfg) -> Dict[str, object]:
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    store = WeightStore(params, 0)
    pub = ResilientPublisher(
        store, faults=FaultPlan.from_strings(["publish_fail@0x2"]),
        max_retries=5, seed=0)
    t0 = time.perf_counter()
    attempts = pub.publish(params, 1)
    recovery_s = time.perf_counter() - t0
    row = {"attempts": attempts, "retries": pub.retries,
           "recovery_s": recovery_s,
           "store_version_after": store.version}
    csv.add("resilience/publish_recovery", recovery_s,
            derived=f"attempts={attempts} retries={pub.retries} "
                    f"v={store.version}")
    assert store.version == 1
    return row


def run(csv: CsvOut, *, quick: bool = False, save_json: bool = True) -> None:
    cfg = toy_config()
    rl = RLConfig(group_size=2, num_minibatches=1, learning_rate=2e-4,
                  max_staleness=3)

    crash = _worker_crash_mttr(csv, cfg, rl, steps=3 if quick else 4,
                               crashes=1 if quick else 2)
    everies = [1, 2] if quick else [1, 2, 4]
    lost = _steps_lost_vs_ckpt_every(csv, cfg, rl,
                                     num_steps=4 if quick else 6,
                                     crash_at=3 if quick else 5,
                                     everies=everies)
    ckpt = _ckpt_latency(csv, cfg, rl)
    pub = _publish_recovery(csv, cfg)

    headline = {
        "worker_crash_mttr_mean_s": crash["mttr_mean_s"],
        "worker_crash_steps_lost": crash["steps_lost"],
        "steps_lost_by_ckpt_every": {
            str(r["ckpt_every"]): r["steps_lost"] for r in lost},
        "bit_exact_resume": all(r["bit_exact_resume"] for r in lost),
        "ckpt_save_ms": round(ckpt["save_s"] * 1e3, 3),
        "ckpt_restore_ms": round(ckpt["restore_s"] * 1e3, 3),
        "publish_recovery_attempts": pub["attempts"],
    }
    print(f"# mttr={crash['mttr_mean_s'] * 1e3:.0f}ms "
          f"steps_lost={headline['steps_lost_by_ckpt_every']} "
          f"bit_exact={headline['bit_exact_resume']} "
          f"ckpt save/restore={headline['ckpt_save_ms']:.0f}/"
          f"{headline['ckpt_restore_ms']:.0f}ms")
    if save_json:
        OUT_JSON.write_text(json.dumps(
            {"bench": "resilience", "arch": cfg.name,
             "headline": headline,
             "worker_crash": crash, "steps_lost": lost,
             "checkpoint": ckpt, "publish": pub},
            indent=2) + "\n")
        print(f"# wrote {OUT_JSON}")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: fewer steps/crashes; does not "
                        "overwrite the committed JSON")
    args = p.parse_args()
    csv = CsvOut()
    csv.header()
    run(csv, quick=args.quick, save_json=not args.quick)


if __name__ == "__main__":
    main()
