"""Reproduce the §Perf hillclimb measurements (EXPERIMENTS.md §4).

Runs baseline + optimized dry-runs for the three chosen pairs and prints
the before/after roofline terms. Must run in its own process (forces the
512-device host platform):

  PYTHONPATH=src:. python -m benchmarks.bench_hillclimb

NOTE: the codeqwen pair's 3.5x win (EXPERIMENTS §4.1) was an activation-
constraint *code fix* that is now part of the baseline itself, so this
script shows only the residual hoist_gather delta for that pair; the
deepseek-coder (kv_seq+tp_fallback) and qwen3-moe (EP dispatch) gains are
config-level and reproduce here (10.9x / 28.1x on the dominant term).
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))


def main() -> None:
    from repro.launch.dryrun import dryrun_one

    pairs = [
        ("codeqwen1.5-7b", "train_4k", {}, {"hoist_gather": True}),
        ("deepseek-coder-33b", "decode_32k", {},
         {"kv_seq_shard": True, "fsdp": False, "tp_fallback": True}),
        ("qwen3-moe-30b-a3b", "train_4k", {}, {"ep_moe": True}),
    ]
    print("name,us_per_call,derived")
    for arch, shape, base_kw, opt_kw in pairs:
        rb = dryrun_one(arch, shape, save=False, verbose=False, **base_kw)
        ro = dryrun_one(arch, shape, save=True, verbose=False,
                        tag_suffix="_opt", **opt_kw)
        for name, r in (("baseline", rb), ("optimized", ro)):
            t = r["roofline"]
            print(f"hillclimb/{arch}/{shape}/{name},"
                  f"{max(t['compute_s'], t['memory_s'], t['collective_s'])*1e6:.0f},"
                  f"compute={t['compute_s']:.2f}s memory={t['memory_s']:.2f}s "
                  f"coll={t['collective_s']:.2f}s "
                  f"args={r['memory']['argument_size_in_bytes']/2**30:.1f}GiB")
        speed = (max(rb["roofline"]["collective_s"], rb["roofline"]["memory_s"])
                 / max(ro["roofline"]["collective_s"],
                       ro["roofline"]["memory_s"], 1e-9))
        print(f"hillclimb/{arch}/{shape}/gain,0,{speed:.1f}x on dominant term")


if __name__ == "__main__":
    main()
