"""Paper Fig. 1: proximal-policy logprob computation time.

Compares, at fixed batch/sequence size:
  * recompute — the explicit forward pass of decoupled PPO (model-scale)
  * loglinear — the A-3PO elementwise interpolation (model-free)
  * a3po_fused — our beyond-paper fused Pallas kernel path (ref on CPU)

The paper reports >= 3000x at 1.5B/8B scale on GPU; the ratio grows with
model size since loglinear cost is independent of the network.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import CsvOut, time_fn, toy_config
from repro.configs.base import RLConfig
from repro.core.a3po import compute_prox_logp_approximation
from repro.models import model as M
from repro.training.trainer import recompute_prox_logp


def run(csv: CsvOut, model: str = "toy-20m", B: int = 16, T: int = 64
        ) -> None:
    cfg = toy_config(model)
    rl = RLConfig()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 4,
                                cfg.vocab_size)
    behav = -jax.random.uniform(jax.random.PRNGKey(2), (B, T - 1)) * 3
    # a frozen "current logp" standing in for the training loop's live value
    live = -jax.random.uniform(jax.random.PRNGKey(3), (B, T - 1)) * 3
    versions = jax.random.randint(jax.random.PRNGKey(4), (B,), 0, 5)

    t_rec, _ = time_fn(recompute_prox_logp, params, cfg, tokens)
    csv.add(f"fig1/prox_recompute/{model}", t_rec,
            f"B={B} T={T} params={cfg.num_params()/1e6:.1f}M")

    approx = jax.jit(lambda b, l, v: compute_prox_logp_approximation(
        b, l, v, 5, rl))
    t_ll, _ = time_fn(approx, behav, live, versions)
    csv.add(f"fig1/prox_loglinear/{model}", t_ll,
            f"speedup={t_rec / t_ll:.0f}x")

    from repro.kernels.a3po_loss import a3po_loss_fused
    alpha = jnp.full((B, T - 1), 0.5)
    adv = jax.random.normal(jax.random.PRNGKey(5), (B, T - 1))
    mask = jnp.ones((B, T - 1))
    fused = jax.jit(lambda lp, bl, al, ad, mk: a3po_loss_fused(
        lp, bl, al, ad, mk))
    t_f, _ = time_fn(fused, live, behav, alpha, adv, mask)
    csv.add(f"fig1/a3po_fused_loss/{model}", t_f,
            "fused prox+IW+clip+mask (beyond-paper)")


if __name__ == "__main__":
    c = CsvOut()
    c.header()
    run(c)
