"""Radix prefix cache benchmark: GRPO-style grouped prompts.

The serving win the control plane targets: ``group_size`` rollouts of the
*same* prompt should prefill it once. Reports prefill tokens actually
computed and end-to-end tokens/s with the cache off vs on, plus the
prefill-token reduction factor (acceptance: >= 1.5x for n=8 identical
prompts).

Run: PYTHONPATH=src:. python -m benchmarks.bench_prefix_cache
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import CsvOut, toy_config
from repro.models import model as M
from repro.rollout.continuous import ContinuousBatchingEngine
from repro.serving.prefix_cache import RadixPrefixCache


def _serve_group(cfg, params, prompt, *, group: int, max_new: int,
                 cached: bool):
    eng = ContinuousBatchingEngine(cfg, max_seqs=group, block_size=4,
                                   n_blocks=256, max_blocks_per_seq=16,
                                   greedy=True)
    if cached:
        eng.prefix_cache = RadixPrefixCache(eng.allocator,
                                            eng.state.block_size)
    for _ in range(group):
        eng.submit(prompt, max_new=max_new)
    t0 = time.perf_counter()
    done = eng.run(params, jax.random.PRNGKey(1))
    dt = time.perf_counter() - t0
    prefill_computed = sum(len(r.prompt) - r.prefix_hit_tokens for r in done)
    gen_tokens = sum(len(r.generated) for r in done)
    return done, prefill_computed, gen_tokens, dt


def run(csv: CsvOut, group: int = 8, prompt_len: int = 16,
        max_new: int = 8) -> float:
    cfg = toy_config()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(4, cfg.vocab_size, size=prompt_len).astype(np.int32)

    results = {}
    for cached in (False, True):
        done, prefill, gen, dt = _serve_group(
            cfg, params, prompt, group=group, max_new=max_new, cached=cached)
        assert len(done) == group
        label = "on" if cached else "off"
        results[label] = (prefill, gen, dt)
        csv.add(f"prefix_cache_{label}_n{group}", dt,
                f"prefill_tokens={prefill};tok_s={gen / dt:.1f}")

    # identical outputs with and without the cache is part of the contract
    reduction = results["off"][0] / max(results["on"][0], 1)
    csv.add(f"prefix_cache_reduction_n{group}", 0.0,
            f"prefill_token_reduction={reduction:.2f}x")
    return reduction


if __name__ == "__main__":
    csv = CsvOut()
    csv.header()
    r = run(csv)
    print(f"# prefill-token reduction: {r:.2f}x (target >= 1.5x)")
    assert r >= 1.5, f"prefix cache reduction {r:.2f}x below 1.5x target"
