"""Shared benchmark utilities."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import jax

from repro.obs.tracing import span


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            label: Optional[str] = None, **kwargs) -> Tuple[float, object]:
    """Median wall time (seconds) of fn(*args) with block_until_ready.

    ``label`` names a tracer span around each timed iteration (no-op when
    no tracer is installed), so benchmark hot spots land in trace.json
    alongside the phase spans the workload itself emits."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    name = label or getattr(fn, "__name__", "bench_fn")
    times: List[float] = []
    for i in range(iters):
        t0 = time.perf_counter()
        with span(name, iter=i):
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def toy_config(name: str = "toy-2m"):
    from repro.configs.registry import get_config
    return dataclasses.replace(get_config(name), dtype="float32")


class CsvOut:
    """Collects ``name,us_per_call,derived`` rows."""

    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, seconds: float, derived: str = "") -> None:
        self.rows.append((name, seconds * 1e6, derived))
        print(f"{name},{seconds * 1e6:.2f},{derived}", flush=True)

    def header(self) -> None:
        print("name,us_per_call,derived", flush=True)
