"""Load-harness SLO bench: priority/SLO-aware scheduling vs FIFO under
overload.

A bursty 2-class trace (latency-critical ``interactive`` + best-effort
``bulk``) is replayed through the serving control plane at ~2x the
engine's virtual capacity, once per policy. FIFO is the no-priority
baseline: interactive requests queue behind bulk, so their TTFT tail
blows through the SLO. The ``slo`` policy admits by priority, sheds
requests that can no longer meet their deadline, and preempts bulk
decodes when an interactive request is about to miss — trading bulk tail
latency for interactive goodput.

Everything is on the virtual clock (deterministic), so the committed
``experiments/load_slo.json`` is reproducible byte-for-byte. Headline:
interactive TTFT p99 and SLO-attainment, slo vs fifo.

The ``--multiarch`` mode replays the same trace against all three
serving architectures — dense attention (``toy-2m``), pure-SSM
(``mamba2-370m-reduced``), hybrid (``zamba2-1.2b-reduced``) — each on a
virtual clock scaled by that architecture's *fitted* ``CostModel``
coefficients (``repro.loadgen.costfit``), so the one table compares how
the same overload trace lands on genuinely different machines. The
committed JSON pins coefficients fitted once on the dev machine (wall
fits are machine-specific); ``--fit`` re-fits live.

Run directly (``python -m benchmarks.bench_load [--quick] [--multiarch]``)
or as the ``load`` / ``load_multiarch`` sections of ``benchmarks.run``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
from typing import Dict, List

import jax

from benchmarks.common import CsvOut, toy_config
from repro.loadgen.harness import CostModel, run_trace
from repro.loadgen.traces import SLOClass, TraceConfig, synthesize
from repro.models import model as M

OUT_JSON = (pathlib.Path(__file__).resolve().parent.parent / "experiments"
            / "load_slo.json")
OUT_MULTIARCH_JSON = OUT_JSON.with_name("load_multiarch.json")

# 2-class mix: the SLO contrast is sharpest with one latency-critical
# class competing against a bulk majority
CLASSES = (
    SLOClass("interactive", 0, ttft_slo_s=0.5, e2e_slo_s=5.0,
             share=0.3, max_new=8),
    SLOClass("bulk", 2, ttft_slo_s=6.0, e2e_slo_s=30.0,
             share=0.7, max_new=16),
)

# inflated virtual costs: shrink capacity so a small trace (cheap on CI
# wall-clock) still produces genuine queueing overload
COST = CostModel(step_overhead_s=0.010, prefill_chunk_s=0.020,
                 decode_token_s=0.010)

# (arch, arch_type) per serving tower; dtype is replaced with float32 so
# CPU replays are deterministic across BLAS paths
MULTIARCH = (("toy-2m", "dense"), ("mamba2-370m-reduced", "ssm"),
             ("zamba2-1.2b-reduced", "hybrid"))

# coefficients fitted by repro.loadgen.costfit.fit_cost_model on the dev
# machine (CPU backend, defaults) — pinned so the committed JSON is
# reproducible; refit live with --fit. The *ratios* carry the signal:
# hybrid decode ~10x the dense toy per token, SSM ~4x. A common scale
# factor (ratio-preserving, like the inflated COST above) shrinks the
# virtual capacity so the small trace still overloads each engine.
COST_SCALE = 25.0
FITTED_COSTS = {
    "toy-2m": CostModel(step_overhead_s=0.0016573,
                        prefill_chunk_s=0.0023972,
                        decode_token_s=0.0000812),
    "mamba2-370m-reduced": CostModel(step_overhead_s=0.0007072,
                                     prefill_chunk_s=0.0021537,
                                     decode_token_s=0.0003143),
    "zamba2-1.2b-reduced": CostModel(step_overhead_s=0.0012215,
                                     prefill_chunk_s=0.0065457,
                                     decode_token_s=0.0008083),
}


def _one(cfg, params, trace, *, policy: str) -> Dict[str, object]:
    res = run_trace(cfg, params, trace, policy=policy, cost=COST,
                    max_seqs=2, decode_horizon=4, prefill_chunk=16)
    s = res.summary
    return {"policy": policy, "requests": s["requests"],
            "completed": s["completed"], "dropped": s["dropped"],
            "steps": s["steps"], "virtual_time_s": s["virtual_time_s"],
            "classes": s["classes"], "serving": s["serving"]}


def run(csv: CsvOut, *, quick: bool = False, save_json: bool = True) -> None:
    cfg = toy_config()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if quick:
        tc = TraceConfig(seed=0, duration_s=1.5, rate_rps=12.0,
                         burstiness=0.6)
        policies = ["fifo", "slo"]
    else:
        tc = TraceConfig(seed=0, duration_s=4.0, rate_rps=15.0,
                         burstiness=0.6)
        policies = ["fifo", "priority", "slo"]
    trace = synthesize(tc, CLASSES)

    rows: List[Dict[str, object]] = []
    for policy in policies:
        r = _one(cfg, params, trace, policy=policy)
        rows.append(r)
        inter = r["classes"]["interactive"]
        csv.add(f"load/{policy}",
                r["virtual_time_s"] / max(r["requests"], 1),
                derived=f"done={r['completed']}/{r['requests']} "
                        f"inter_ttft_p99={inter['ttft_p99_s'] * 1e3:.0f}ms "
                        f"inter_slo={inter['slo_attainment'] * 100:.0f}% "
                        f"shed={r['serving']['drops_slo_shed']}")

    by = {r["policy"]: r for r in rows}
    fifo_i = by["fifo"]["classes"]["interactive"]
    slo_i = by["slo"]["classes"]["interactive"]
    headline = {
        "interactive_ttft_p99_s": {"fifo": fifo_i["ttft_p99_s"],
                                   "slo": slo_i["ttft_p99_s"]},
        "interactive_slo_attainment": {
            "fifo": fifo_i["slo_attainment"],
            "slo": slo_i["slo_attainment"]},
        "interactive_goodput_rps": {"fifo": fifo_i["goodput_rps"],
                                    "slo": slo_i["goodput_rps"]},
        "ttft_p99_speedup": round(
            fifo_i["ttft_p99_s"] / max(slo_i["ttft_p99_s"], 1e-9), 3),
    }
    print(f"# interactive ttft_p99 fifo={fifo_i['ttft_p99_s'] * 1e3:.0f}ms "
          f"slo={slo_i['ttft_p99_s'] * 1e3:.0f}ms "
          f"({headline['ttft_p99_speedup']:.1f}x); "
          f"attainment {fifo_i['slo_attainment'] * 100:.0f}% -> "
          f"{slo_i['slo_attainment'] * 100:.0f}%")
    if save_json:
        OUT_JSON.write_text(json.dumps(
            {"bench": "load_slo",
             "classes": [dict(c.to_dict()) for c in CLASSES],
             "trace": {"seed": tc.seed, "duration_s": tc.duration_s,
                       "rate_rps": tc.rate_rps,
                       "burstiness": tc.burstiness,
                       "requests": len(trace.requests)},
             "cost_model": {"step_overhead_s": COST.step_overhead_s,
                            "prefill_chunk_s": COST.prefill_chunk_s,
                            "decode_token_s": COST.decode_token_s},
             "max_seqs": 2, "decode_horizon": 4,
             "headline": headline, "rows": rows},
            indent=2) + "\n")
        print(f"# wrote {OUT_JSON}")


def run_multiarch(csv: CsvOut, *, quick: bool = False,
                  save_json: bool = True, fit: bool = False) -> None:
    """One trace, three serving architectures, one SLO table."""
    from repro.configs.registry import get_config
    from repro.loadgen.costfit import describe, fit_cost_model

    if quick:
        tc = TraceConfig(seed=0, duration_s=1.0, rate_rps=10.0,
                         burstiness=0.6)
    else:
        tc = TraceConfig(seed=0, duration_s=3.0, rate_rps=12.0,
                         burstiness=0.6)
    trace = synthesize(tc, CLASSES)

    rows: List[Dict[str, object]] = []
    for arch, arch_type in MULTIARCH:
        cfg = dataclasses.replace(get_config(arch), dtype="float32")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        fitted = fit_cost_model(cfg, params) if fit \
            else FITTED_COSTS[arch]
        cost = CostModel(
            step_overhead_s=fitted.step_overhead_s * COST_SCALE,
            prefill_chunk_s=fitted.prefill_chunk_s * COST_SCALE,
            decode_token_s=fitted.decode_token_s * COST_SCALE)
        res = run_trace(cfg, params, trace, policy="slo", cost=cost,
                        max_seqs=2, decode_horizon=4, prefill_chunk=16)
        s = res.summary
        row = {"arch": arch, "arch_type": arch_type,
               "cost_model": {"step_overhead_s": fitted.step_overhead_s,
                              "prefill_chunk_s": fitted.prefill_chunk_s,
                              "decode_token_s": fitted.decode_token_s},
               "requests": s["requests"], "completed": s["completed"],
               "dropped": s["dropped"], "steps": s["steps"],
               "virtual_time_s": s["virtual_time_s"],
               "classes": s["classes"], "serving": s["serving"]}
        rows.append(row)
        inter = row["classes"]["interactive"]
        csv.add(f"load_multiarch/{arch_type}",
                row["virtual_time_s"] / max(row["requests"], 1),
                derived=f"arch={arch} "
                        f"done={row['completed']}/{row['requests']} "
                        f"inter_ttft_p99={inter['ttft_p99_s'] * 1e3:.0f}ms "
                        f"inter_slo={inter['slo_attainment'] * 100:.0f}% "
                        f"cost[{describe(cost)}]")

    by = {r["arch_type"]: r for r in rows}
    headline = {
        "virtual_time_s": {t: by[t]["virtual_time_s"] for t in by},
        "interactive_slo_attainment": {
            t: by[t]["classes"]["interactive"]["slo_attainment"]
            for t in by},
        "interactive_ttft_p99_s": {
            t: by[t]["classes"]["interactive"]["ttft_p99_s"] for t in by},
        "decode_token_cost_ratio": {
            t: round(by[t]["cost_model"]["decode_token_s"]
                     / by["dense"]["cost_model"]["decode_token_s"], 3)
            for t in by},
    }
    print("# multiarch (policy=slo): "
          + "; ".join(
              f"{t} vtime={by[t]['virtual_time_s']:.2f}s slo="
              f"{by[t]['classes']['interactive']['slo_attainment'] * 100:.0f}%"
              for t in ("dense", "ssm", "hybrid")))
    if save_json:
        OUT_MULTIARCH_JSON.write_text(json.dumps(
            {"bench": "load_multiarch", "policy": "slo",
             "cost_fit": "pinned" if not fit else "live",
             "cost_scale": COST_SCALE,
             "classes": [dict(c.to_dict()) for c in CLASSES],
             "trace": {"seed": tc.seed, "duration_s": tc.duration_s,
                       "rate_rps": tc.rate_rps,
                       "burstiness": tc.burstiness,
                       "requests": len(trace.requests)},
             "max_seqs": 2, "decode_horizon": 4,
             "headline": headline, "rows": rows},
            indent=2) + "\n")
        print(f"# wrote {OUT_MULTIARCH_JSON}")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: tiny trace, fifo+slo only; does not "
                        "overwrite the committed JSON")
    p.add_argument("--multiarch", action="store_true",
                   help="replay one trace against dense/ssm/hybrid "
                        "serving with per-arch cost models")
    p.add_argument("--fit", action="store_true",
                   help="with --multiarch: re-fit cost models live "
                        "instead of using the pinned coefficients")
    args = p.parse_args()
    csv = CsvOut()
    csv.header()
    if args.multiarch:
        run_multiarch(csv, quick=args.quick,
                      save_json=not args.quick and not args.fit,
                      fit=args.fit)
    else:
        run(csv, quick=args.quick, save_json=not args.quick)


if __name__ == "__main__":
    main()
