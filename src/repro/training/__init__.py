from repro.training.checkpoints import (  # noqa: F401
    load_checkpoint,
    save_checkpoint,
)
from repro.training.optimizer import adam_init, adam_update  # noqa: F401
from repro.training.trainer import (  # noqa: F401
    TrainBatch,
    Trainer,
    TrainState,
    assemble_train_batch,
    recompute_prox_logp,
    score_tokens,
    sft_update,
)
