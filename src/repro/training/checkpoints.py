"""Dependency-free pytree checkpointing (npz + json metadata).

Crash consistency: a checkpoint is the *pair* (``<name>.npz``,
``<name>.json``) committed atomically. ``save_checkpoint`` stages both
files in a temp dir next to the target, fsyncs them, then ``os.replace``s
the npz first and the json second (and fsyncs the directory). The json
carries a CRC32 of the npz bytes, so it doubles as the commit record: a
crash between the two replaces leaves a checksum mismatch that
``load_checkpoint`` turns into ``CheckpointError`` instead of silently
restoring torn state. ``resilience.checkpoint.CheckpointManager`` builds
step-named checkpoints + a ``latest`` pointer on top of this primitive.
"""
from __future__ import annotations

import json
import os
import tempfile
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

# metadata keys owned by the checkpoint format itself
_CHECKSUM_KEY = "__npz_crc32__"
_FORMAT_KEY = "__format__"
_FORMAT_VERSION = 1


class CheckpointError(RuntimeError):
    """Missing, torn, or corrupt checkpoint."""


def _flatten(tree, path="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{path}/{k}" if path else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{path}/#{i}"))
    else:
        out[path] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict):
            if node and all(k.startswith("#") for k in node):
                return [fix(node[f"#{i}"]) for i in range(len(node))]
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


def _paths(path: str) -> Tuple[str, str]:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".npz", base + ".json"


def _file_crc32(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(chunk, crc)
    return crc & 0xFFFFFFFF


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return  # platform without directory fds
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save_checkpoint(path: str, tree: Any,
                    metadata: Optional[Dict[str, Any]] = None) -> None:
    """Atomically write ``tree`` (npz) + ``metadata`` (json) as one unit.

    Both files are staged in a temp dir on the same filesystem, fsynced,
    then published with ``os.replace`` — npz before json, so the json
    (which embeds the npz checksum) commits the pair. Any crash leaves
    either the previous complete checkpoint or a detectable mismatch,
    never a silently torn one.
    """
    directory = os.path.dirname(path) or "."
    os.makedirs(directory, exist_ok=True)
    npz_path, meta_path = _paths(path)
    flat = _flatten(jax.device_get(tree))
    tmpdir = tempfile.mkdtemp(dir=directory, prefix=".ckpt-tmp-")
    try:
        tmp_npz = os.path.join(tmpdir, "tree.npz")
        tmp_meta = os.path.join(tmpdir, "meta.json")
        with open(tmp_npz, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        meta = dict(metadata or {})
        meta[_CHECKSUM_KEY] = _file_crc32(tmp_npz)
        meta[_FORMAT_KEY] = _FORMAT_VERSION
        with open(tmp_meta, "w") as f:
            json.dump(meta, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_npz, npz_path)
        os.replace(tmp_meta, meta_path)
        _fsync_dir(directory)
    finally:
        # only staging leftovers remain on failure; the publish itself
        # moved the files out
        for name in ("tree.npz", "meta.json"):
            p = os.path.join(tmpdir, name)
            if os.path.exists(p):
                os.unlink(p)
        os.rmdir(tmpdir)


def load_checkpoint(path: str, verify: bool = True
                    ) -> Tuple[Any, Dict[str, Any]]:
    """Load (tree, metadata); with ``verify`` (default) recompute the npz
    checksum against the committed one and raise ``CheckpointError`` on a
    torn/corrupt pair."""
    npz_path, meta_path = _paths(path)
    if not os.path.exists(npz_path):
        raise CheckpointError(f"checkpoint not found: {npz_path}")
    meta: Dict[str, Any] = {}
    if os.path.exists(meta_path):
        try:
            with open(meta_path) as f:
                meta = json.load(f)
        except ValueError as e:
            raise CheckpointError(
                f"corrupt checkpoint metadata {meta_path}: {e}") from e
    elif verify:
        # the json is the commit record of the pair — a lone npz is a
        # crash between the two os.replace publishes, never a valid state
        raise CheckpointError(
            f"checkpoint {npz_path} has no committed metadata "
            f"({meta_path} missing): torn write?")
    if verify and _CHECKSUM_KEY in meta:
        crc = _file_crc32(npz_path)
        if crc != int(meta[_CHECKSUM_KEY]):
            raise CheckpointError(
                f"checkpoint checksum mismatch for {npz_path}: npz crc32 "
                f"{crc:#010x} != committed {int(meta[_CHECKSUM_KEY]):#010x}"
                " (torn write?)")
    try:
        with np.load(npz_path) as data:
            flat = {k: data[k] for k in data.files}
    except Exception as e:  # zipfile/np errors on truncated files
        raise CheckpointError(f"unreadable checkpoint {npz_path}: {e}") from e
    meta = {k: v for k, v in meta.items()
            if k not in (_CHECKSUM_KEY, _FORMAT_KEY)}
    return _unflatten(flat), meta


def restore_sharded(path: str, shardings: Any) -> Tuple[Any, Dict[str, Any]]:
    """Load a checkpoint and place every leaf on its mesh sharding.

    ``shardings`` mirrors the saved tree (e.g. from
    ``models.param_shardings``); leaves land directly on devices in their
    distributed layout — the restore path a multi-host deployment uses
    after the per-host files are assembled.
    """
    import jax

    tree, meta = load_checkpoint(path)
    placed = jax.tree.map(
        lambda arr, sh: jax.device_put(jax.numpy.asarray(arr), sh),
        tree, shardings)
    return placed, meta
