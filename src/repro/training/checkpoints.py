"""Dependency-free pytree checkpointing (npz + json metadata)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, path="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{path}/{k}" if path else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{path}/#{i}"))
    else:
        out[path] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict):
            if node and all(k.startswith("#") for k in node):
                return [fix(node[f"#{i}"]) for i in range(len(node))]
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


def save_checkpoint(path: str, tree: Any,
                    metadata: Optional[Dict[str, Any]] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".json"
    with open(meta_path, "w") as f:
        json.dump(metadata or {}, f, indent=2)


def load_checkpoint(path: str) -> Tuple[Any, Dict[str, Any]]:
    npz = path if path.endswith(".npz") else path + ".npz"
    with np.load(npz) as data:
        flat = {k: data[k] for k in data.files}
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".json"
    meta = {}
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    return _unflatten(flat), meta


def restore_sharded(path: str, shardings: Any) -> Tuple[Any, Dict[str, Any]]:
    """Load a checkpoint and place every leaf on its mesh sharding.

    ``shardings`` mirrors the saved tree (e.g. from
    ``models.param_shardings``); leaves land directly on devices in their
    distributed layout — the restore path a multi-host deployment uses
    after the per-host files are assembled.
    """
    import jax

    tree, meta = load_checkpoint(path)
    placed = jax.tree.map(
        lambda arr, sh: jax.device_put(jax.numpy.asarray(arr), sh),
        tree, shardings)
    return placed, meta
