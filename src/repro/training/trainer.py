"""RL trainer: scoring, prox recompute, minibatched A-3PO/decoupled/coupled
updates — the training engine of the async system.

Matches the paper's procedure (§4.1): one *training step* consumes a rollout
batch, optionally recomputes the proximal policy with an extra forward pass
(method='recompute' — the cost A-3PO deletes), then performs
``num_minibatches`` gradient updates with the frozen anchor.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.core.advantages import group_normalized_advantages
from repro.core.losses import policy_loss
from repro.kernels.logprob import token_logprob_entropy
from repro.models import model as M
from repro.models.layers import output_head_weight
from repro.rollout.engine import RolloutBatch
from repro.training.optimizer import adam_init, adam_update


class TrainState(NamedTuple):
    params: Any
    opt: Any
    version: jax.Array  # int32 scalar — the target-policy version v(pi_theta)


@dataclasses.dataclass
class TrainBatch:
    """Device-ready training batch assembled from rollouts."""

    tokens: jax.Array        # [B, T]
    response_mask: jax.Array  # [B, T-1] (1 on generated-token predictions)
    behav_logp: jax.Array    # [B, T-1] (0 outside mask)
    # behavior policy versions: [B] (one per sequence) or [B, T-1]
    # (per-token stamps from the interruptible serving control plane)
    versions: jax.Array
    rewards: jax.Array       # [B]


def assemble_train_batch(rollouts: List[RolloutBatch],
                         rewards: np.ndarray) -> TrainBatch:
    """Scatter ragged generation logps into [B, T-1] aligned tensors.

    If any rollout carries per-token version stamps (``gen_versions``,
    produced when generation crossed a weight publish), ``versions`` is
    emitted as [B, T-1] so ``a3po.staleness`` sees the true per-token
    ``d`` — the alpha interpolation then varies *within* a sequence at
    the publish boundary. Otherwise the legacy [B] form is kept.
    """
    tokens = np.concatenate([r.tokens for r in rollouts], axis=0)
    B, T = tokens.shape
    behav = np.zeros((B, T - 1), np.float32)
    mask = np.zeros((B, T - 1), np.float32)
    per_token = any(r.gen_versions is not None for r in rollouts)
    if per_token:
        versions = np.zeros((B, T - 1), np.int32)
    else:
        versions = np.zeros((B,), np.int32)
    row = 0
    for r in rollouts:
        N = r.gen_logp.shape[1]
        for b in range(r.batch_size):
            L = int(r.prompt_lengths[b])
            # position t predicts tokens[t+1]; first generated token is
            # predicted at t = L-1
            behav[row, L - 1: L - 1 + N] = r.gen_logp[b]
            mask[row, L - 1: L - 1 + N] = r.gen_mask[b]
            if per_token:
                versions[row, :] = r.version
                if r.gen_versions is not None:
                    versions[row, L - 1: L - 1 + N] = np.where(
                        r.gen_mask[b] > 0, r.gen_versions[b], r.version)
            else:
                versions[row] = r.version
            row += 1
    return TrainBatch(
        tokens=jnp.asarray(tokens),
        response_mask=jnp.asarray(mask),
        behav_logp=jnp.asarray(behav),
        versions=jnp.asarray(versions),
        rewards=jnp.asarray(rewards, jnp.float32),
    )


# --------------------------------------------------------------------- score
@functools.partial(jax.jit, static_argnames=("cfg",))
def score_tokens(params, cfg: ModelConfig, tokens: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-position logp of tokens[t+1] + entropy. Returns ([B,T-1]x2, aux).

    Uses the fused logprob kernel path — the [T, V] logits never
    materialize (this is exactly the computation the 'recompute' baseline
    pays for every training step).
    """
    hidden, aux = M.forward_hidden(params, cfg, tokens[:, :-1])
    w = output_head_weight(params["embedding"], cfg)
    logp, entropy = token_logprob_entropy(hidden, w, tokens[:, 1:])
    return logp, entropy, aux


@functools.partial(jax.jit, static_argnames=("cfg",))
def recompute_prox_logp(params, cfg: ModelConfig, tokens: jax.Array
                        ) -> jax.Array:
    """The explicit proximal forward pass of decoupled PPO (Hilton 2022).

    This is the per-step cost A-3PO eliminates (paper Fig. 1)."""
    logp, _, _ = score_tokens(params, cfg, tokens)
    return jax.lax.stop_gradient(logp)


# ---------------------------------------------------------------------- loss
def _loss_fn(params, cfg: ModelConfig, rl: RLConfig, method: str,
             tokens, behav_logp, advantages, mask, versions,
             current_version, prox_logp):
    logp, entropy, aux = score_tokens.__wrapped__(params, cfg, tokens)
    loss, metrics = policy_loss(
        method, logp, behav_logp, advantages, mask, rl,
        versions=versions, current_version=current_version,
        recomputed_prox_logp=prox_logp, entropy=entropy)
    return loss + aux, metrics


# NOTE: params are NOT donated — the async runtime keeps older versions
# alive as behavior policies; only the optimizer state is safe to donate.
@functools.partial(jax.jit, static_argnames=("cfg", "rl", "method"),
                   donate_argnums=(4,))
def minibatch_update(cfg: ModelConfig, rl: RLConfig, method: str,
                     params, opt, current_version,
                     tokens, behav_logp, advantages, mask, versions,
                     prox_logp):
    (loss, metrics), grads = jax.value_and_grad(
        _loss_fn, has_aux=True)(params, cfg, rl, method, tokens, behav_logp,
                                advantages, mask, versions, current_version,
                                prox_logp)
    params, opt, gnorm = adam_update(grads, opt, params, rl)
    metrics = dict(metrics, loss=loss, grad_norm=gnorm)
    return params, opt, metrics


# -------------------------------------------------------------------- driver
class Trainer:
    """One training engine. ``step`` = the paper's 'training step'."""

    def __init__(self, cfg: ModelConfig, rl: Optional[RLConfig] = None,
                 method: str = "loglinear"):
        assert method in ("loglinear", "recompute", "sync")
        self.cfg = cfg
        self.rl = rl or RLConfig()
        self.method = method

    def init_state(self, key, dtype=None) -> TrainState:
        params = M.init_params(self.cfg, key, dtype=dtype)
        return TrainState(params, adam_init(params),
                          jnp.zeros((), jnp.int32))

    def step(self, state: TrainState, batch: TrainBatch
             ) -> Tuple[TrainState, Dict[str, float]]:
        rl = self.rl
        adv_seq = group_normalized_advantages(batch.rewards, rl.group_size)
        advantages = adv_seq[:, None] * batch.response_mask

        # --- explicit prox forward pass (recompute baseline only)
        t0 = time.perf_counter()
        if self.method == "recompute":
            prox = recompute_prox_logp(state.params, self.cfg, batch.tokens)
            prox.block_until_ready()
        else:
            prox = jnp.zeros_like(batch.behav_logp)  # unused placeholder
        prox_time = time.perf_counter() - t0

        params, opt = state.params, state.opt
        B = batch.tokens.shape[0]
        nmb = min(rl.num_minibatches, B)
        mb = B // nmb
        all_metrics: List[Dict[str, jax.Array]] = []
        for i in range(nmb):
            sl = slice(i * mb, (i + 1) * mb)
            params, opt, metrics = minibatch_update(
                self.cfg, rl, self.method, params, opt, state.version,
                batch.tokens[sl], batch.behav_logp[sl], advantages[sl],
                batch.response_mask[sl], batch.versions[sl], prox[sl])
            all_metrics.append(metrics)

        out = {k: float(np.mean([float(m[k]) for m in all_metrics]))
               for k in all_metrics[0]}
        out["iw_max"] = float(np.max([float(m["iw_max"])
                                      for m in all_metrics]))
        out["iw_min"] = float(np.min([float(m["iw_min"])
                                      for m in all_metrics]))
        out["clipped_tokens"] = float(np.sum([float(m["clipped_tokens"])
                                              for m in all_metrics]))
        out["prox_time_s"] = prox_time
        out["reward_mean"] = float(batch.rewards.mean())
        d = state.version - batch.versions
        if batch.versions.ndim == 2:
            # per-token stamps: average over response tokens only (prompt
            # positions carry a filler version, not behavior staleness)
            msum = float(jnp.sum(batch.response_mask))
            out["staleness_mean"] = float(
                jnp.sum(d * batch.response_mask) / max(msum, 1.0))
        else:
            out["staleness_mean"] = float(d.mean())
        new_state = TrainState(params, opt, state.version + 1)
        return new_state, out


# ----------------------------------------------------------------- SFT warmup
@functools.partial(jax.jit, static_argnames=("cfg", "lr"), donate_argnums=(2,))
def sft_update(cfg: ModelConfig, params, opt, tokens, mask, lr: float = 1e-3):
    rl = RLConfig(learning_rate=lr, max_grad_norm=1.0)

    def loss_fn(p):
        logp, _, aux = score_tokens.__wrapped__(p, cfg, tokens)
        ce = -jnp.sum(logp * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt, _ = adam_update(grads, opt, params, rl)
    return params, opt, loss
