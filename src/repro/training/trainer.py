"""RL training engine: one compiled, mesh-sharded update per training step.

Matches the paper's procedure (§4.1): one *training step* consumes a rollout
batch, optionally recomputes the proximal policy with an extra forward pass
(method='recompute' — the cost A-3PO deletes), then performs
``num_minibatches`` gradient updates with the frozen anchor.

Engine architecture (PR 2): the whole update path is a single jitted
``train_step`` — advantages, a ``lax.scan`` over minibatches (each with an
optional inner gradient-accumulation scan over microbatches), Adam, and
metric accumulation all run on device. Metrics are packed into one array,
so a training step costs exactly **one** host transfer (plus the explicit
prox forward for the 'recompute' baseline, which is the point of the
comparison). Params and Adam moments are placed with the active
``ShardingEnv``'s logical rules, and batch tensors carry ("pod","data")
sharding constraints.

Algorithm dispatch (PR 3): the engine takes a first-class ``Algorithm``
(``core.algorithms``) instead of a method string. The frozen instance is
hashed as a jit static, its ``loss`` runs inside the scan (the ``a3po``
built-in still compiles to the fused ``kernels/a3po_loss`` Pallas path),
and its requires-flags decide what the step computes at all: only
``needs_prox_forward`` algorithms pay the extra forward pass, and only
``needs_behav_logp`` / ``needs_versions`` algorithms get those tensors
threaded through the compiled minibatch scan.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.core.algorithms import Algorithm, LossInputs, resolve_algorithm
from repro.distributed.sharding import constrain, current_env
from repro.kernels.logprob import token_logprob_entropy
from repro.models import model as M
from repro.models.layers import output_head_weight
from repro.obs.metrics import get_registry
from repro.obs.tracing import annotate, span
from repro.rollout.engine import RolloutBatch
from repro.training.optimizer import adam_init, adam_update


class TrainState(NamedTuple):
    params: Any
    opt: Any
    version: jax.Array  # int32 scalar — the target-policy version v(pi_theta)


@dataclasses.dataclass
class TrainBatch:
    """Device-ready training batch assembled from rollouts."""

    tokens: jax.Array        # [B, T]
    response_mask: jax.Array  # [B, T-1] (1 on generated-token predictions)
    behav_logp: jax.Array    # [B, T-1] (0 outside mask)
    # behavior policy versions: [B] (one per sequence) or [B, T-1]
    # (per-token stamps from the interruptible serving control plane)
    versions: jax.Array
    rewards: jax.Array       # [B]


def assemble_train_batch(rollouts: List[RolloutBatch],
                         rewards: np.ndarray) -> TrainBatch:
    """Scatter ragged generation logps into [B, T-1] aligned tensors.

    If any rollout carries per-token version stamps (``gen_versions``,
    produced when generation crossed a weight publish), ``versions`` is
    emitted as [B, T-1] so ``a3po.staleness`` sees the true per-token
    ``d`` — the alpha interpolation then varies *within* a sequence at
    the publish boundary. Otherwise the legacy [B] form is kept.

    The scatter is vectorized: position t predicts tokens[t+1], so row b's
    generated span starts at column prompt_lengths[b] - 1 — one fancy-index
    write per rollout instead of a per-sequence Python loop.
    """
    tokens = np.concatenate([r.tokens for r in rollouts], axis=0)
    B, T = tokens.shape
    behav = np.zeros((B, T - 1), np.float32)
    mask = np.zeros((B, T - 1), np.float32)
    per_token = any(r.gen_versions is not None for r in rollouts)
    if per_token:
        versions = np.zeros((B, T - 1), np.int32)
    else:
        versions = np.zeros((B,), np.int32)
    row = 0
    for r in rollouts:
        N = r.gen_logp.shape[1]
        rows = slice(row, row + r.batch_size)
        cols = (np.asarray(r.prompt_lengths, np.int64) - 1)[:, None] \
            + np.arange(N)[None, :]
        np.put_along_axis(behav[rows], cols,
                          np.asarray(r.gen_logp, np.float32), axis=1)
        np.put_along_axis(mask[rows], cols,
                          np.asarray(r.gen_mask, np.float32), axis=1)
        if per_token:
            versions[rows] = r.version
            if r.gen_versions is not None:
                stamped = np.where(r.gen_mask > 0, r.gen_versions,
                                   r.version).astype(np.int32)
                np.put_along_axis(versions[rows], cols, stamped, axis=1)
        else:
            versions[rows] = r.version
        row += r.batch_size
    return TrainBatch(
        tokens=jnp.asarray(tokens),
        response_mask=jnp.asarray(mask),
        behav_logp=jnp.asarray(behav),
        versions=jnp.asarray(versions),
        rewards=jnp.asarray(rewards, jnp.float32),
    )


# --------------------------------------------------------------------- score
def _score_tokens(params, cfg: ModelConfig, tokens: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    tokens = constrain(tokens, "batch", None)
    hidden, aux = M.forward_hidden(params, cfg, tokens[:, :-1])
    w = output_head_weight(params["embedding"], cfg)
    logp, entropy = token_logprob_entropy(hidden, w, tokens[:, 1:])
    return (constrain(logp, "batch", None), constrain(entropy, "batch", None),
            aux)


@functools.partial(jax.jit, static_argnames=("cfg",))
def score_tokens(params, cfg: ModelConfig, tokens: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-position logp of tokens[t+1] + entropy. Returns ([B,T-1]x2, aux).

    Uses the fused logprob kernel path — the [T, V] logits never
    materialize (this is exactly the computation the 'recompute' baseline
    pays for every training step).
    """
    return _score_tokens(params, cfg, tokens)


@functools.partial(jax.jit, static_argnames=("cfg",))
def recompute_prox_logp(params, cfg: ModelConfig, tokens: jax.Array
                        ) -> jax.Array:
    """The explicit proximal forward pass of decoupled PPO (Hilton 2022).

    This is the per-step cost A-3PO eliminates (paper Fig. 1)."""
    logp, _, _ = _score_tokens(params, cfg, tokens)
    return jax.lax.stop_gradient(logp)


# --------------------------------------------------------------- fused step
# Fixed pack order for the on-device metrics vector — a single [K] f32
# array is the step's one device->host transfer.
METRIC_KEYS: Tuple[str, ...] = (
    "clipped_frac", "clipped_tokens", "entropy", "grad_norm", "iw_max",
    "iw_mean", "iw_min", "kl", "loss", "nonfinite", "ratio_mean",
    "reward_mean", "staleness_mean", "tokens",
)


def _reduce_metrics(stacked: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Fold [n]-stacked per-minibatch metrics: means, except extremes/sums
    (exactly the seed loop-trainer's host-side aggregation, on device)."""
    out = {k: jnp.mean(v, axis=0) for k, v in stacked.items()}
    if "iw_max" in stacked:
        out["iw_max"] = jnp.max(stacked["iw_max"], axis=0)
    if "iw_min" in stacked:
        out["iw_min"] = jnp.min(stacked["iw_min"], axis=0)
    if "clipped_tokens" in stacked:
        out["clipped_tokens"] = jnp.sum(stacked["clipped_tokens"], axis=0)
    if "nonfinite" in stacked:
        # minibatches whose update was non-finite: a count, not a mean
        out["nonfinite"] = jnp.sum(stacked["nonfinite"], axis=0)
    return out


def _constrain_batch(t: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    return {k: constrain(v, *(("batch",) + (None,) * (v.ndim - 1)))
            for k, v in t.items()}


def _train_step_impl(params, opt, version, tokens, behav_logp, mask,
                     versions, rewards, prox_logp=None, *, cfg: ModelConfig,
                     rl: RLConfig, algo: Algorithm, num_minibatches: int,
                     num_microbatches: int, skip_nonfinite: bool = False):
    """One full training step, compiled: advantages -> scan over minibatch
    updates (optionally gradient-accumulated over microbatches) -> packed
    metrics. Exactly one output array carries every scalar metric. The
    ``algo`` static supplies the loss and its requires-flags decide which
    batch tensors are threaded through the minibatch scan at all."""
    B = tokens.shape[0]
    nmb = num_minibatches
    mb_size = B // nmb
    nmi = (num_microbatches
           if num_microbatches > 1 and mb_size % num_microbatches == 0 else 1)

    full = _constrain_batch(dict(tokens=tokens, behav_logp=behav_logp,
                                 mask=mask, versions=versions,
                                 rewards=rewards))
    tokens, behav_logp, mask, versions, rewards = (
        full["tokens"], full["behav_logp"], full["mask"], full["versions"],
        full["rewards"])

    advantages = algo.advantages(rewards, mask, rl)

    # full-batch staleness/reward telemetry (matches the seed trainer)
    d = version.astype(jnp.float32) - versions.astype(jnp.float32)
    if versions.ndim == 2:
        # per-token stamps: average over response tokens only (prompt
        # positions carry a filler version, not behavior staleness)
        staleness_mean = jnp.sum(d * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        staleness_mean = d.mean()

    # requires-flags gate what enters the compiled minibatch scan: an
    # algorithm that declares no use for behavior logps or version stamps
    # never sees them (and XLA never materializes the minibatched copies)
    mbt = dict(tokens=tokens, advantages=advantages, mask=mask)
    if algo.needs_behav_logp:
        mbt["behav_logp"] = behav_logp
    if algo.needs_versions:
        mbt["versions"] = versions
    if prox_logp is not None:
        mbt["prox"] = prox_logp
    # seed semantics: rows beyond nmb * mb_size are dropped from updates
    # (but still count toward reward/staleness telemetry above)
    mbt = jax.tree.map(
        lambda x: x[: nmb * mb_size].reshape((nmb, mb_size) + x.shape[1:]),
        mbt)

    def loss_fn(p, t):
        t = _constrain_batch(t)
        logp, entropy, aux = _score_tokens(p, cfg, t["tokens"])
        loss, metrics = algo.loss(logp, LossInputs(
            advantages=t["advantages"], mask=t["mask"],
            behav_logp=t.get("behav_logp"), versions=t.get("versions"),
            current_version=version, prox_logp=t.get("prox"),
            entropy=entropy), rl)
        return loss + aux, metrics

    def grads_of(p, t):
        if nmi == 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(p, t)
        micro = jax.tree.map(
            lambda x: x.reshape((nmi, mb_size // nmi) + x.shape[1:]), t)
        g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)

        # Accumulate weighted by each microbatch's response-token count:
        # the losses are masked *means*, so an equal average would
        # over-weight tokens in sparse microbatches relative to the
        # single-pass minibatch objective.
        def accum(carry, mi):
            g_acc, loss_acc, w_acc = carry
            w = jnp.sum(mi["mask"])
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p, mi)
            g_acc = jax.tree.map(
                lambda a, g: a + w * g.astype(jnp.float32), g_acc, grads)
            return (g_acc, loss_acc + w * loss, w_acc + w), metrics

        (grads, loss, w_tot), ms = jax.lax.scan(
            accum, (g0, jnp.zeros((), jnp.float32),
                    jnp.zeros((), jnp.float32)), micro)
        w_tot = jnp.maximum(w_tot, 1.0)
        grads = jax.tree.map(lambda g: g / w_tot, grads)
        return (loss / w_tot, _reduce_metrics(ms)), grads

    def minibatch_body(carry, t):
        p, o = carry
        (loss, metrics), grads = grads_of(p, t)
        p2, o2, gnorm = adam_update(grads, o, p, rl)
        # on-device non-finite guard: grad_norm is a global reduction, so
        # any NaN/Inf gradient leaf poisons it — one scalar flag covers
        # loss + every gradient, and it rides the packed metric array
        # (zero extra host syncs).
        ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        if skip_nonfinite:
            # poisoned minibatch: keep params AND the whole Adam state
            # (moments + step count) bit-identical — the update never
            # happened (resilience.guards skip-step policy)
            sel = lambda new, old: jnp.where(ok, new, old)  # noqa: E731
            p = jax.tree.map(sel, p2, p)
            o = jax.tree.map(sel, o2, o)
        else:
            p, o = p2, o2
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       nonfinite=(~ok).astype(jnp.float32))
        return (p, o), metrics

    (params, opt), stacked = jax.lax.scan(minibatch_body, (params, opt), mbt)
    out = _reduce_metrics(stacked)
    out["reward_mean"] = rewards.mean()
    out["staleness_mean"] = staleness_mean
    # response tokens that actually received a gradient (rows past
    # nmb * mb_size are dropped from the scan, so don't count them)
    out["tokens"] = jnp.sum(mask[: nmb * mb_size])
    assert set(out) == set(METRIC_KEYS), sorted(out)
    packed = jnp.stack([out[k].astype(jnp.float32) for k in METRIC_KEYS])
    return params, opt, packed


_STEP_STATICS = ("cfg", "rl", "algo", "num_minibatches", "num_microbatches",
                 "skip_nonfinite")
# Default engine donates only the optimizer state: the async runtime keeps
# older params alive as behavior policies (WeightStore / staleness history),
# so donating them would invalidate live behavior-policy buffers.
_train_step = jax.jit(_train_step_impl, static_argnames=_STEP_STATICS,
                      donate_argnums=(1,))
# Opt-in variant for pure synchronous loops that never re-read old params:
# donates params + opt, letting XLA update weights and moments in place.
_train_step_donating = jax.jit(_train_step_impl,
                               static_argnames=_STEP_STATICS,
                               donate_argnums=(0, 1))


# -------------------------------------------------------------------- driver
class Trainer:
    """One training engine. ``step`` = the paper's 'training step'.

    ``algo`` selects the policy-optimization algorithm: an ``Algorithm``
    instance from ``core.algorithms``, a registry name, or None (falls
    back to ``rl.algo`` / the deprecated ``rl.method`` string). The legacy
    ``method=`` keyword still works but emits a ``DeprecationWarning``.

    ``num_microbatches`` > 1 adds gradient accumulation *inside* the
    minibatch scan for batches that exceed memory. ``donate_params=True``
    selects the params-donating compiled step (only safe when no other
    component holds the previous weights)."""

    def __init__(self, cfg: ModelConfig, rl: Optional[RLConfig] = None,
                 algo=None, *, method: Optional[str] = None,
                 num_microbatches: int = 1, donate_params: bool = False,
                 skip_nonfinite: bool = False):
        if method is not None:
            warnings.warn(
                "Trainer(..., method=...) is deprecated; pass an Algorithm "
                "or registry name as `algo` (repro.core.algorithms)",
                DeprecationWarning, stacklevel=2)
            if algo is None:
                algo = method
        self.cfg = cfg
        self.rl = rl or RLConfig()
        self.algo = resolve_algorithm(algo, self.rl)
        self.num_microbatches = num_microbatches
        self.donate_params = donate_params
        # skip-step guard: non-finite minibatch updates are dropped on
        # device (params/opt unchanged) instead of poisoning the run; the
        # packed `nonfinite` metric counts them (resilience.guards)
        self.skip_nonfinite = skip_nonfinite
        self.last_host_syncs = 0  # host transfers in the most recent step

    @property
    def method(self) -> str:
        """Legacy spelling: the resolved algorithm's registry name."""
        return self.algo.name

    def init_state(self, key, dtype=None) -> TrainState:
        """Initialize params + Adam moments, placed with the active
        ``ShardingEnv``'s logical-axis rules when one is installed."""
        params = M.init_params(self.cfg, key, dtype=dtype)
        opt = adam_init(params)
        env = current_env()
        if env is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            psh = M.param_shardings(self.cfg, env)
            params = jax.device_put(params, psh)
            opt = {
                "m": jax.device_put(opt["m"], psh),
                "v": jax.device_put(opt["v"], psh),
                "t": jax.device_put(opt["t"],
                                    NamedSharding(env.mesh, PartitionSpec())),
            }
        return TrainState(params, opt, jnp.zeros((), jnp.int32))

    def step(self, state: TrainState, batch: TrainBatch
             ) -> Tuple[TrainState, Dict[str, float]]:
        rl = self.rl
        B = batch.tokens.shape[0]
        nmb = min(rl.num_minibatches, B)
        if self.num_microbatches > 1 \
                and (B // nmb) % self.num_microbatches != 0:
            raise ValueError(
                f"num_microbatches={self.num_microbatches} does not divide "
                f"the minibatch size {B // nmb} (B={B}, nmb={nmb}); the "
                "memory-saving accumulation would be silently skipped")
        host_syncs = 0

        # --- explicit prox forward pass, paid only by algorithms that
        # declare needs_prox_forward (the recompute baseline); otherwise
        # no prox operand enters the compiled step at all
        t0 = time.perf_counter()
        prox = None
        if self.algo.needs_prox_forward:
            with span("prox_forward", algo=self.algo.name), \
                    annotate("prox_forward"):
                prox = recompute_prox_logp(state.params, self.cfg,
                                           batch.tokens)
                prox.block_until_ready()
            host_syncs += 1
        prox_time = time.perf_counter() - t0

        with span("train_update", algo=self.algo.name,
                  batch=int(B), minibatches=int(nmb)), \
                annotate("train_update"):
            step_fn = (_train_step_donating if self.donate_params
                       else _train_step)
            params, opt, packed = step_fn(
                state.params, state.opt, state.version, batch.tokens,
                batch.behav_logp, batch.response_mask, batch.versions,
                batch.rewards, prox, cfg=self.cfg, rl=rl, algo=self.algo,
                num_minibatches=nmb,
                num_microbatches=self.num_microbatches,
                skip_nonfinite=self.skip_nonfinite)

            # the single device->host transfer of the step
            values = jax.device_get(packed)
        host_syncs += 1
        out = {k: float(v) for k, v in zip(METRIC_KEYS, values)}
        out["prox_time_s"] = prox_time
        out["host_syncs"] = float(host_syncs)
        self.last_host_syncs = host_syncs
        self._publish_metrics(out)
        new_state = TrainState(params, opt, state.version + 1)
        return new_state, out

    # training-side metrics mirrored into the process-wide obs registry
    # (gauges: latest step's value; counters: lifetime accumulation), so
    # one ``registry.snapshot()`` / prometheus dump covers trainer state
    # alongside the serving facade.
    _GAUGE_KEYS = ("loss", "reward_mean", "entropy", "grad_norm",
                   "iw_max", "iw_min", "iw_mean", "kl", "clipped_frac",
                   "ratio_mean", "staleness_mean", "prox_time_s")
    _COUNTER_KEYS = ("tokens", "clipped_tokens", "host_syncs", "nonfinite")

    def _publish_metrics(self, out: Dict[str, float]) -> None:
        reg = get_registry()
        for k in self._GAUGE_KEYS:
            if k in out:
                reg.gauge(f"train_{k}").set(out[k])
        for k in self._COUNTER_KEYS:
            if k in out:
                reg.counter(f"train_{k}_total").inc(out[k])
        reg.counter("train_steps_total").inc()


# ----------------------------------------------------------------- SFT warmup
@functools.partial(jax.jit, static_argnames=("cfg", "lr"), donate_argnums=(2,))
def sft_update(cfg: ModelConfig, params, opt, tokens, mask, lr: float = 1e-3):
    rl = RLConfig(learning_rate=lr, max_grad_norm=1.0)

    def loss_fn(p):
        logp, _, aux = _score_tokens(p, cfg, tokens)
        ce = -jnp.sum(logp * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt, _ = adam_update(grads, opt, params, rl)
    return params, opt, loss
