"""Adam(W) with global-norm clipping — fp32 states, hand-rolled in JAX."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RLConfig

OptState = Dict[str, Any]


def adam_init(params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "t": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adam_update(grads, state: OptState, params, rl: RLConfig
                ) -> Tuple[Any, OptState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, rl.max_grad_norm / (gnorm + 1e-9))
    t = state["t"] + 1
    b1, b2, eps = rl.adam_b1, rl.adam_b2, rl.adam_eps
    c1 = 1.0 - b1 ** t.astype(jnp.float32)
    c2 = 1.0 - b2 ** t.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = rl.learning_rate * (m / c1) / (jnp.sqrt(v / c2) + eps)
        if rl.weight_decay:
            step = step + rl.learning_rate * rl.weight_decay \
                * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "t": t}, gnorm
