"""Step-named crash-consistent checkpoints + ``latest`` pointer.

Built on the atomic ``training.checkpoints`` primitive (tmp + fsync +
``os.replace`` of the npz/json pair with an embedded checksum). The
manager adds what a resumable async run needs:

* step-named checkpoints (``step_00000004.npz/json``) with bounded
  retention — a torn write of step k can never damage step k-1;
* a ``latest`` pointer file, itself atomically replaced, naming the last
  committed checkpoint;
* a full capture of everything bit-exact resume requires: params, Adam
  state, weight version, the rollout PRNG key, the task's numpy RNG
  state, and the staleness history (behavior-policy param snapshots);
* ``restore_latest`` that falls back to scanning (newest valid first)
  when the pointer or its target is torn/corrupt.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import get_registry
from repro.obs.tracing import instant
from repro.training.checkpoints import (
    CheckpointError,
    load_checkpoint,
    save_checkpoint,
)
from repro.training.trainer import TrainState

_LATEST = "latest"
_STEP_RE = re.compile(r"^(?P<prefix>.+)_(?P<step>\d{8})\.json$")


@dataclasses.dataclass
class ResumeInfo:
    """Everything needed to continue a run from a checkpoint."""

    state: TrainState
    step: int                       # first step index still to run
    key: Optional[Any] = None       # rollout PRNG key (jax uint32[2])
    history: Optional[List[Tuple[Any, int]]] = None  # staleness history
    task_rng_state: Optional[Dict] = None   # numpy Generator state dict
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)
    path: str = ""


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 prefix: str = "step"):
        self.directory = directory
        self.keep = keep
        self.prefix = prefix
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- paths
    def path_for(self, step: int) -> str:
        return os.path.join(self.directory, f"{self.prefix}_{step:08d}")

    def _latest_pointer(self) -> str:
        return os.path.join(self.directory, _LATEST)

    def _scan(self) -> List[Tuple[int, str]]:
        """(step, base path) of every on-disk checkpoint, newest first."""
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and m.group("prefix") == self.prefix:
                base = os.path.join(self.directory, name[:-5])
                if os.path.exists(base + ".npz"):
                    out.append((int(m.group("step")), base))
        return sorted(out, reverse=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: TrainState, *, key=None,
             history: Optional[List[Tuple[Any, int]]] = None,
             task_rng_state: Optional[Dict] = None,
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Commit a checkpoint for resuming *at* ``step`` (i.e. steps
        ``0..step-1`` are done). Returns the base path."""
        tree: Dict[str, Any] = {"params": state.params, "opt": state.opt}
        if key is not None:
            tree["key"] = np.asarray(key)
        if history is not None:
            tree["history"] = [
                {"params": p, "version": np.int32(v)} for p, v in history]
        meta: Dict[str, Any] = dict(extra or {})
        meta["step"] = int(step)
        meta["version"] = int(state.version)
        meta["has_key"] = key is not None
        meta["has_history"] = history is not None
        if task_rng_state is not None:
            meta["task_rng_state"] = task_rng_state
        path = self.path_for(step)
        save_checkpoint(path, tree, meta)
        self._write_latest(step, path)
        self._retain()
        get_registry().counter("resilience_checkpoint_saves_total").inc()
        instant("checkpoint_saved", step=step, version=meta["version"])
        return path

    def _write_latest(self, step: int, base_path: str) -> None:
        ptr = {"step": int(step),
               "name": os.path.basename(base_path)}
        fd, tmp = tempfile.mkstemp(dir=self.directory, prefix=".latest-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(ptr, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._latest_pointer())
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def _retain(self) -> None:
        for _, base in self._scan()[self.keep:]:
            for ext in (".npz", ".json"):
                try:
                    os.unlink(base + ext)
                except OSError:
                    pass

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        info = self._read_pointer()
        if info is not None:
            return info[0]
        scan = self._scan()
        return scan[0][0] if scan else None

    def _read_pointer(self) -> Optional[Tuple[int, str]]:
        try:
            with open(self._latest_pointer()) as f:
                ptr = json.load(f)
            base = os.path.join(self.directory, ptr["name"])
            if os.path.exists(base + ".npz"):
                return int(ptr["step"]), base
        except (OSError, ValueError, KeyError):
            pass
        return None

    def restore(self, base_path: str) -> ResumeInfo:
        tree, meta = load_checkpoint(base_path)
        version = int(meta.get("version", 0))
        state = TrainState(tree["params"], tree["opt"],
                           jnp.asarray(version, jnp.int32))
        key = jnp.asarray(tree["key"]) if "key" in tree else None
        history = None
        if "history" in tree:
            history = [(h["params"], int(h["version"]))
                       for h in tree["history"]]
        info = ResumeInfo(state=state, step=int(meta.get("step", 0)),
                          key=key, history=history,
                          task_rng_state=meta.get("task_rng_state"),
                          metadata=meta, path=base_path)
        get_registry().counter("resilience_checkpoint_restores_total").inc()
        instant("checkpoint_restored", step=info.step, version=version)
        return info

    def restore_latest(self) -> Optional[ResumeInfo]:
        """Restore the newest *valid* checkpoint: the ``latest`` pointer
        first, then a newest-first scan skipping torn/corrupt pairs.
        Returns None when the directory holds no usable checkpoint."""
        tried = set()
        candidates: List[Tuple[int, str]] = []
        ptr = self._read_pointer()
        if ptr is not None:
            candidates.append(ptr)
        candidates.extend(self._scan())
        for _, base in candidates:
            if base in tried:
                continue
            tried.add(base)
            try:
                return self.restore(base)
            except CheckpointError:
                get_registry().counter(
                    "resilience_checkpoint_corrupt_total").inc()
                continue
        return None
