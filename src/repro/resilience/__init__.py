"""Fault tolerance for the async runtime.

Pieces (each independently usable):

* ``faults``     — seeded deterministic fault-injection plane
                   (``FaultPlan``, ``--fault KIND@STEP`` grammar);
* ``supervisor`` — heartbeat-monitored worker threads with bounded
                   seeded-backoff restarts + deadlock-free queue pops;
* ``guards``     — non-finite update policies (on-device detection rides
                   the packed metric array) and a divergence detector;
* ``checkpoint`` — crash-consistent step-named checkpoints with a
                   ``latest`` pointer and full-RNG capture for bit-exact
                   resume;
* ``publish``    — weight-publish retries with backoff while serving
                   keeps decoding the old version.

``ResilienceConfig`` bundles them for ``AsyncOrchestrator`` /
``simulate_async``; every event lands in the ``resilience_*`` counter
family (``faults.resilience_snapshot``) and as tracer instants.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.resilience.checkpoint import CheckpointManager, ResumeInfo
from repro.resilience.faults import (
    FAULT_KINDS,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    parse_fault,
    resilience_snapshot,
)
from repro.resilience.guards import (
    GUARD_POLICIES,
    DivergenceDetector,
    GuardVerdict,
    TrainGuard,
)
from repro.resilience.publish import PublishError, ResilientPublisher
from repro.resilience.supervisor import (
    CrashRecord,
    SupervisedWorker,
    WorkerFailed,
    pop_with_health,
)

__all__ = [
    "FAULT_KINDS", "GUARD_POLICIES", "CheckpointManager", "CrashRecord",
    "DivergenceDetector", "FaultPlan", "FaultSpec", "GuardVerdict",
    "InjectedFault", "PublishError", "ResilienceConfig",
    "ResilientPublisher", "ResumeInfo", "SupervisedWorker", "TrainGuard",
    "WorkerFailed", "parse_fault", "pop_with_health",
    "resilience_snapshot",
]


@dataclasses.dataclass
class ResilienceConfig:
    """Everything the async runtime needs to survive and resume.

    ``ckpt_every`` > 0 (with a ``checkpointer``) commits a checkpoint
    after every N completed steps; ``pop_deadline_s`` bounds the
    trainer's wait for a fresh rollout batch before declaring the
    producer dead.
    """

    faults: Optional[FaultPlan] = None
    guard: Optional[TrainGuard] = None
    checkpointer: Optional[CheckpointManager] = None
    ckpt_every: int = 0
    max_worker_restarts: int = 3
    heartbeat_timeout_s: float = 60.0
    pop_deadline_s: float = 120.0
    publish_max_retries: int = 5
    seed: int = 0

    def maybe_checkpoint(self, step_done: int) -> bool:
        """Should a checkpoint be committed after ``step_done``?"""
        return (self.checkpointer is not None and self.ckpt_every > 0
                and (step_done + 1) % self.ckpt_every == 0)
