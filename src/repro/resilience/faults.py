"""Deterministic seeded fault-injection plane.

A ``FaultPlan`` is a list of ``FaultSpec``s plus a seeded RNG. Components
(the orchestrator, the simulator, the serving control plane, the loadgen
harness) call ``plan.check(kind)`` at well-defined *sites*; the plan keeps
one occurrence counter per site, so "crash the rollout worker at its 2nd
rollout" or "fail the 1st weight publish 3 times" is exactly reproducible
run-to-run. Every fired fault is counted in the ``resilience_*`` metric
family and marked with an instant event in the tracer.

Spec string grammar (the ``--fault`` CLI flag)::

    KIND@AT            fire once, at the AT-th site occurrence (0-based)
    KIND@ATxTIMES      fire on TIMES consecutive occurrences
    KIND@AT:MAG        magnitude (seconds of delay, blocks to steal, ...)
    KIND@ATxTIMES:MAG  both

Kinds and their sites:

==============  ========================================================
rollout_crash   rollout worker, start of each rollout -> raise
train_crash     trainer loop, start of each step -> raise (kill/resume)
publish_fail    weight publish attempt -> simulated failure (retried)
publish_delay   weight publish -> sleep(magnitude) before publishing
queue_stall     rollout worker, before queue push -> sleep(magnitude)
nan_grad        trainer loop, per step -> NaN into one reward (loss and
                grads go non-finite; the on-device guard must catch it)
kv_exhaust      serving step -> hold `magnitude` free KV blocks for the
                spec's TIMES consecutive serving steps
nan_logits      serving step -> NaN row in the decode logits buffer
==============  ========================================================
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs.metrics import get_registry
from repro.obs.tracing import instant

FAULT_KINDS = (
    "rollout_crash", "train_crash", "publish_fail", "publish_delay",
    "queue_stall", "nan_grad", "kv_exhaust", "nan_logits",
)


class InjectedFault(RuntimeError):
    """Raised by crash-type faults; carries the spec that fired."""

    def __init__(self, spec: "FaultSpec", occurrence: int):
        super().__init__(
            f"injected fault {spec.kind}@{occurrence}"
            + (f" (magnitude {spec.magnitude:g})" if spec.magnitude else ""))
        self.spec = spec
        self.occurrence = occurrence


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    kind: str
    at: int                 # 0-based site-occurrence index of the first fire
    times: int = 1          # consecutive occurrences to fire on
    magnitude: float = 0.0  # delay seconds / blocks to hold / ...

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}")
        if self.at < 0 or self.times < 1:
            raise ValueError(f"bad fault window: at={self.at} "
                             f"times={self.times}")

    def spec_str(self) -> str:
        s = f"{self.kind}@{self.at}"
        if self.times != 1:
            s += f"x{self.times}"
        if self.magnitude:
            s += f":{self.magnitude:g}"
        return s


def parse_fault(text: str) -> FaultSpec:
    """Parse ``KIND@AT[xTIMES][:MAG]`` (the ``--fault`` flag grammar)."""
    if "@" not in text:
        raise ValueError(f"fault spec {text!r}: expected KIND@AT[xN][:MAG]")
    kind, rest = text.split("@", 1)
    magnitude = 0.0
    if ":" in rest:
        rest, mag = rest.split(":", 1)
        magnitude = float(mag)
    times = 1
    if "x" in rest:
        rest, t = rest.split("x", 1)
        times = int(t)
    return FaultSpec(kind=kind.strip(), at=int(rest), times=times,
                     magnitude=magnitude)


class FaultPlan:
    """Seeded, deterministic fault schedule shared across components.

    Thread-safe enough for the async runtime: per-site counters are only
    advanced from the single thread that owns that site (trainer loop,
    rollout worker, serving step), and the fired-event list append is
    protected by the GIL. ``rng`` gives faults that need randomness (which
    NaN row, jitter) a seeded stream independent of the training RNG.
    """

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: int = 0):
        self.specs: List[FaultSpec] = list(specs)
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._counts: Dict[str, int] = {}
        self.fired: List[Dict] = []   # {kind, occurrence, magnitude}

    @classmethod
    def from_strings(cls, texts: Sequence[str], seed: int = 0) -> "FaultPlan":
        return cls([parse_fault(t) for t in texts], seed=seed)

    def __bool__(self) -> bool:
        return bool(self.specs)

    def check(self, kind: str) -> Optional[FaultSpec]:
        """Advance the ``kind`` site counter; return the spec that fires
        at this occurrence (None when healthy)."""
        i = self._counts.get(kind, 0)
        self._counts[kind] = i + 1
        for spec in self.specs:
            if spec.kind == kind and spec.at <= i < spec.at + spec.times:
                self.fired.append({"kind": kind, "occurrence": i,
                                   "magnitude": spec.magnitude})
                get_registry().counter("resilience_faults_injected_total",
                                       kind=kind).inc()
                instant("fault_injected", kind=kind, occurrence=i,
                        magnitude=spec.magnitude)
                return spec
        return None

    def maybe_crash(self, kind: str) -> None:
        """``check`` + raise ``InjectedFault`` when the fault fires."""
        spec = self.check(kind)
        if spec is not None:
            raise InjectedFault(spec, self._counts[kind] - 1)

    def occurrences(self, kind: str) -> int:
        return self._counts.get(kind, 0)


def resilience_snapshot() -> Dict[str, float]:
    """The ``resilience_*`` slice of the process metrics registry — what
    the orchestrator attaches to ``StepRecord.resilience``."""
    return {k: v for k, v in get_registry().snapshot().items()
            if k.startswith("resilience_")}
