"""Training guards: non-finite update policies + windowed divergence.

The *detection* is on-device and free: the compiled training step checks
``isfinite(loss) & isfinite(grad_norm)`` per minibatch (``grad_norm`` is a
global reduction, so any NaN/Inf gradient leaf poisons it) and, with
``Trainer(skip_nonfinite=True)``, applies the Adam update through a
``jnp.where`` on that flag — a poisoned minibatch leaves params/opt
bit-identical instead of spreading NaNs. The count of skipped minibatch
updates rides in the existing packed metric array (``nonfinite`` key), so
the guard costs **zero extra host syncs**.

``TrainGuard`` is the host-side policy layer the orchestrator consults
once per step with that (already transferred) metric dict:

* ``policy="skip"``   — count skipped updates; training continues (the
  on-device where already protected the params).
* ``policy="rollback"`` — additionally restore the latest checkpoint when
  a step reports non-finite updates or the windowed divergence detector
  trips.
* ``policy="off"``    — observe only.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, Optional

from repro.obs.metrics import get_registry
from repro.obs.tracing import instant

GUARD_POLICIES = ("off", "skip", "rollback")


class DivergenceDetector:
    """Windowed loss-divergence detector.

    Trips when the newest loss is non-finite, or exceeds
    ``mean + threshold_sigmas * std`` of the trailing window (computed
    *excluding* the newest sample, with at least ``min_window`` history).
    """

    def __init__(self, window: int = 16, threshold_sigmas: float = 6.0,
                 min_window: int = 8):
        self.window = window
        self.threshold_sigmas = threshold_sigmas
        self.min_window = min_window
        self._losses: Deque[float] = deque(maxlen=window)

    def update(self, loss: float) -> bool:
        """Feed one step loss; True when this step looks divergent (the
        divergent sample is *not* folded into the window)."""
        if not math.isfinite(loss):
            return True
        hist = list(self._losses)
        tripped = False
        if len(hist) >= self.min_window:
            mean = sum(hist) / len(hist)
            var = sum((x - mean) ** 2 for x in hist) / len(hist)
            std = math.sqrt(var)
            if std > 0 and loss > mean + self.threshold_sigmas * std:
                tripped = True
        if not tripped:
            self._losses.append(loss)
        return tripped

    def reset(self) -> None:
        self._losses.clear()


@dataclasses.dataclass
class GuardVerdict:
    action: str                 # "ok" | "skip" | "rollback"
    nonfinite_updates: float = 0.0
    diverged: bool = False


class TrainGuard:
    """Per-step policy over the packed metrics the step already produced."""

    def __init__(self, policy: str = "skip",
                 detector: Optional[DivergenceDetector] = None):
        assert policy in GUARD_POLICIES, policy
        self.policy = policy
        self.detector = detector or DivergenceDetector()
        self.skipped_updates = 0
        self.rollbacks = 0
        self.divergences = 0

    def after_step(self, metrics: Dict[str, float]) -> GuardVerdict:
        """Inspect one step's metric dict; returns the verdict the caller
        acts on (``rollback`` => restore the latest checkpoint)."""
        nonfinite = float(metrics.get("nonfinite", 0.0))
        reg = get_registry()
        if self.policy == "off":
            return GuardVerdict("ok", nonfinite)
        diverged = False
        loss = float(metrics.get("loss", 0.0))
        if math.isfinite(loss) or nonfinite == 0.0:
            # a step whose every minibatch was skipped reports a NaN loss
            # mean; only feed the detector meaningful losses
            diverged = self.detector.update(loss)
            if diverged:
                self.divergences += 1
                reg.counter("resilience_divergences_total").inc()
                instant("divergence_detected", loss=loss)
        if nonfinite > 0:
            self.skipped_updates += int(nonfinite)
            reg.counter("resilience_skipped_updates_total").inc(nonfinite)
            instant("nonfinite_update_skipped", count=nonfinite)
        if self.policy == "rollback" and (nonfinite > 0 or diverged):
            self.rollbacks += 1
            reg.counter("resilience_rollbacks_total").inc()
            self.detector.reset()
            return GuardVerdict("rollback", nonfinite, diverged)
        if nonfinite > 0:
            return GuardVerdict("skip", nonfinite, diverged)
        return GuardVerdict("ok", nonfinite, diverged)
