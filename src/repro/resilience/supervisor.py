"""Supervised worker threads: heartbeats, capture, bounded restarts.

``SupervisedWorker`` wraps a worker body (e.g. the orchestrator's rollout
loop) in a supervisor thread that:

* runs the body with a ``WorkerContext`` (stop flag + heartbeat stamp);
* captures any exception as a ``CrashRecord`` (type, message, traceback)
  instead of letting the thread die silently;
* restarts the body up to ``max_restarts`` times under exponential
  backoff with seeded jitter (deterministic given the seed);
* flips ``failed`` once the restart budget is exhausted, so consumers
  polling the queue can raise instead of blocking forever.

The consumer side of the contract is ``pop_with_health``: a bounded-
wall-clock queue pop that interleaves short pop timeouts with worker
health checks (permanent failure, heartbeat silence) — the trainer can
never deadlock on a dead or hung producer.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import traceback
from typing import Callable, List, Optional

import numpy as np

from repro.obs.metrics import get_registry
from repro.obs.tracing import instant


@dataclasses.dataclass
class CrashRecord:
    t_crash_s: float          # perf_counter stamp of the crash
    exc_type: str
    message: str
    traceback_str: str
    restart_n: int            # how many restarts had already happened
    t_restarted_s: float = -1.0  # stamp of the successful restart (-1: none)

    @property
    def recovery_s(self) -> float:
        """Crash-to-restart wall time (the per-crash MTTR sample)."""
        return (self.t_restarted_s - self.t_crash_s
                if self.t_restarted_s >= 0 else float("nan"))


class WorkerContext:
    """What a supervised body sees: a stop flag and a heartbeat."""

    def __init__(self, stop_event: threading.Event,
                 heartbeat_fn: Callable[[], None]):
        self._stop = stop_event
        self._beat = heartbeat_fn

    def should_stop(self) -> bool:
        return self._stop.is_set()

    def heartbeat(self) -> None:
        self._beat()


class WorkerFailed(RuntimeError):
    """The supervised producer is permanently down (restart budget spent
    or heartbeat silence) — raised by ``pop_with_health`` instead of a
    deadlocked queue pop."""


class SupervisedWorker:
    """Heartbeat-monitored worker thread with bounded seeded restarts.

    ``target(ctx, *args)`` must loop on ``ctx.should_stop()`` and call
    ``ctx.heartbeat()`` at least once per iteration. A return is a clean
    exit; an exception is a crash (captured + restarted while budget
    remains).
    """

    def __init__(self, name: str, target: Callable, args: tuple = (),
                 *, max_restarts: int = 3, backoff_base_s: float = 0.05,
                 backoff_max_s: float = 2.0, jitter_frac: float = 0.5,
                 heartbeat_timeout_s: float = 60.0, seed: int = 0,
                 stop_event: Optional[threading.Event] = None):
        self.name = name
        self._target = target
        self._args = args
        self.max_restarts = max_restarts
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.jitter_frac = jitter_frac
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self._rng = np.random.default_rng(seed)
        self._stop = stop_event or threading.Event()
        self._lock = threading.Lock()
        self._last_beat = time.perf_counter()
        self.crashes: List[CrashRecord] = []
        self.restarts = 0
        self.failed = False
        self._thread = threading.Thread(target=self._supervise, daemon=True,
                                        name=f"supervised-{name}")

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "SupervisedWorker":
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        self._thread.join(timeout=timeout)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    # ------------------------------------------------------------ heartbeat
    def _heartbeat(self) -> None:
        with self._lock:
            self._last_beat = time.perf_counter()

    def heartbeat_age_s(self) -> float:
        with self._lock:
            return time.perf_counter() - self._last_beat

    @property
    def last_crash(self) -> Optional[CrashRecord]:
        return self.crashes[-1] if self.crashes else None

    def health_error(self) -> Optional[str]:
        """Why this worker can no longer make progress (None = healthy)."""
        if self.failed:
            last = self.last_crash
            detail = f": {last.exc_type}: {last.message}" if last else ""
            return (f"worker {self.name!r} failed permanently after "
                    f"{self.restarts} restarts{detail}")
        if not self.alive and not self._stop.is_set():
            return f"worker {self.name!r} thread exited unexpectedly"
        if self.heartbeat_age_s() > self.heartbeat_timeout_s:
            return (f"worker {self.name!r} heartbeat silent for "
                    f"{self.heartbeat_age_s():.1f}s "
                    f"(> {self.heartbeat_timeout_s:.1f}s)")
        return None

    # ----------------------------------------------------------- supervisor
    def _backoff_s(self, n: int) -> float:
        base = min(self.backoff_base_s * (2.0 ** n), self.backoff_max_s)
        return base * (1.0 + self.jitter_frac * float(self._rng.random()))

    def _supervise(self) -> None:
        ctx = WorkerContext(self._stop, self._heartbeat)
        reg = get_registry()
        while not self._stop.is_set():
            self._heartbeat()
            try:
                self._target(ctx, *self._args)
                return  # clean exit
            except Exception as e:  # noqa: BLE001 — capture everything
                rec = CrashRecord(
                    t_crash_s=time.perf_counter(),
                    exc_type=type(e).__name__, message=str(e),
                    traceback_str=traceback.format_exc(),
                    restart_n=self.restarts)
                self.crashes.append(rec)
                reg.counter("resilience_worker_crashes_total").inc()
                instant("worker_crash", worker=self.name,
                        exc=rec.exc_type, restart_n=self.restarts)
                if self._stop.is_set():
                    return
                if self.restarts >= self.max_restarts:
                    self.failed = True
                    reg.counter("resilience_worker_failures_total").inc()
                    return
                delay = self._backoff_s(self.restarts)
                self.restarts += 1
                reg.counter("resilience_worker_restarts_total").inc()
                # interruptible backoff sleep
                self._stop.wait(delay)
                rec.t_restarted_s = time.perf_counter()
                instant("worker_restart", worker=self.name,
                        restart_n=self.restarts, backoff_s=round(delay, 4))


def pop_with_health(queue, worker: Optional[SupervisedWorker],
                    current_version: int, n: int = 1, *,
                    poll_s: float = 1.0, deadline_s: float = 120.0):
    """``RolloutQueue.pop_fresh`` with bounded wall-clock and producer
    health checks: raises ``WorkerFailed`` (dead/hung producer) or
    ``TimeoutError`` (deadline) instead of blocking forever."""
    from repro.async_rl.buffer import QueueClosed

    t0 = time.perf_counter()
    while True:
        try:
            return queue.pop_fresh(current_version, n=n, timeout=poll_s)
        except QueueClosed:
            raise WorkerFailed(
                "rollout queue closed while the trainer was waiting")
        except TimeoutError:
            pass
        if worker is not None:
            err = worker.health_error()
            if err is not None:
                get_registry().counter(
                    "resilience_queue_timeouts_total").inc()
                raise WorkerFailed(err)
        if time.perf_counter() - t0 > deadline_s:
            get_registry().counter("resilience_queue_timeouts_total").inc()
            raise TimeoutError(
                f"no fresh rollout batch within {deadline_s:.0f}s "
                f"(queue depth {queue.qsize()})")
