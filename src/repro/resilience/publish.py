"""Weight-publish retries with backoff (serving graceful degradation).

``ResilientPublisher`` wraps ``WeightStore.publish``: a failed publish
(injected via the fault plane, or a real exception from a store listener)
is retried under exponential backoff with seeded jitter. Until the retry
lands, serving simply keeps decoding under the previous version — the
store is untouched by a failed attempt, in-flight sequences never see a
half-published version, and per-token staleness stamps stay truthful
(tokens decoded during the outage carry the old version, which *is* the
version that produced them).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.async_rl.weights import WeightStore
from repro.obs.metrics import get_registry
from repro.obs.tracing import instant
from repro.resilience.faults import FaultPlan, InjectedFault


class PublishError(RuntimeError):
    """A weight publish attempt failed (injected or real)."""


class ResilientPublisher:
    def __init__(self, store: WeightStore, *,
                 faults: Optional[FaultPlan] = None, max_retries: int = 5,
                 backoff_base_s: float = 0.01, backoff_max_s: float = 0.5,
                 jitter_frac: float = 0.5, seed: int = 0):
        self.store = store
        self.faults = faults
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.jitter_frac = jitter_frac
        self._rng = np.random.default_rng(seed)
        self.retries = 0      # lifetime retry count
        self.failures = 0     # publishes that exhausted the budget

    def _backoff_s(self, n: int) -> float:
        base = min(self.backoff_base_s * (2.0 ** n), self.backoff_max_s)
        return base * (1.0 + self.jitter_frac * float(self._rng.random()))

    def publish(self, params, version: int) -> int:
        """Publish with retries; returns the number of attempts used.

        Raises ``PublishError`` once ``max_retries`` retries are spent —
        the store still holds the previous version (serving keeps going);
        the caller decides whether that is fatal for training.
        """
        reg = get_registry()
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    spec = self.faults.check("publish_delay")
                    if spec is not None and spec.magnitude > 0:
                        time.sleep(spec.magnitude)
                    self.faults.maybe_crash("publish_fail")
                self.store.publish(params, version)
                if attempt:
                    reg.counter("resilience_publish_recoveries_total").inc()
                    instant("publish_recovered", version=version,
                            attempts=attempt + 1)
                return attempt + 1
            except (InjectedFault, PublishError) as e:
                if attempt >= self.max_retries:
                    self.failures += 1
                    reg.counter("resilience_publish_failures_total").inc()
                    raise PublishError(
                        f"weight publish v{version} failed after "
                        f"{attempt + 1} attempts: {e}") from e
                self.retries += 1
                reg.counter("resilience_publish_retries_total").inc()
                time.sleep(self._backoff_s(attempt))
                attempt += 1
