"""Admission control over the continuous-batching engine's fixed slots.

Three gates sit between ``submit`` and a slot (the "Staleness-Learning
Rate Scaling Laws" prescription: enforce the staleness budget in the
scheduler instead of hoping the queue stays shallow):

* **priority classes** — a binary heap keyed on (priority, arrival), so
  urgent traffic (e.g. the trainer's on-policy refresh batch) overtakes
  bulk rollouts;
* **backpressure** — when the downstream ``RolloutQueue`` is nearly full
  the trainer is the bottleneck, so generating more stale data is pure
  waste: non-urgent admits are held at ``backpressure_high`` and all
  admits at ``backpressure_full``;
* **staleness budget** — a request is never admitted once
  ``now_version - submit_version`` exceeds ``d_max`` (it is dropped, or
  resubmitted fresh by the control plane), and in-flight sequences whose
  oldest token stamp falls behind the budget are preempted, returning all
  their refcounted blocks.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.rollout.continuous import Request


@dataclasses.dataclass
class SchedulerConfig:
    d_max: int = 4                   # staleness budget, in weight versions
    backpressure_high: float = 0.75  # queue depth fraction: hold prio > 0
    backpressure_full: float = 1.0   # queue depth fraction: hold everything
    preempt_action: str = "requeue"  # "requeue" (restart fresh) | "drop"
    max_preempts: int = 2            # requeue at most this many times


class AdmissionScheduler:
    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.config = config or SchedulerConfig()
        self._heap: List[Tuple[int, int, float, Request]] = []
        self._seq = 0
        self.dropped: List[Request] = []

    def __len__(self) -> int:
        return len(self._heap)

    def enqueue(self, req: Request, now_s: float = 0.0) -> None:
        heapq.heappush(self._heap, (req.priority, self._seq, now_s, req))
        self._seq += 1

    def pop_admissible(self, now_version: int, *, engine,
                       queue_frac: float = 0.0
                       ) -> Optional[Tuple[Request, float]]:
        """Best admissible request, or None.

        Requests already past the staleness budget are dropped on the spot
        (collected in ``self.dropped`` for the control plane's resubmit
        policy). Block availability is checked against the engine's
        prefix-cache-aware estimate, with cache eviction as the fallback
        before giving up.
        """
        cfg = self.config
        while self._heap:
            prio, _, t_enq, req = self._heap[0]
            if now_version - req.submit_version > cfg.d_max:
                heapq.heappop(self._heap)
                self.dropped.append(req)
                continue
            if queue_frac >= cfg.backpressure_full:
                return None
            if prio > 0 and queue_frac >= cfg.backpressure_high:
                return None
            needed = engine.blocks_needed(req.prompt, req.max_new)
            if needed > engine.allocator.n_free:
                cache = getattr(engine, "prefix_cache", None)
                if cache is not None:
                    cache.evict(needed - engine.allocator.n_free)
                if needed > engine.allocator.n_free:
                    return None
            heapq.heappop(self._heap)
            return req, t_enq
        return None

    def check_preempt(self, slots: Dict[int, Optional[Request]],
                      now_version: int) -> List[int]:
        """Slots whose oldest token stamp exceeds the staleness budget."""
        out = []
        for slot, req in slots.items():
            if req is None:
                continue
            if now_version - req.min_version() > self.config.d_max:
                out.append(slot)
        return out

    def handle_preempted(self, req: Request, now_version: int,
                         now_s: float = 0.0) -> str:
        """Requeue (restarted fresh) or drop a preempted request.

        Returns the action taken. Requeued requests lose their generated
        tokens — their stamps are already over budget, so the KV and
        partial generation are unusable for training anyway.
        """
        req.preempt_count += 1
        if (self.config.preempt_action == "drop"
                or req.preempt_count > self.config.max_preempts):
            self.dropped.append(req)
            return "drop"
        req.reset_generation()
        req.submit_version = now_version
        self.enqueue(req, now_s)
        return "requeue"

    def take_dropped(self) -> List[Request]:
        out, self.dropped = self.dropped, []
        return out
