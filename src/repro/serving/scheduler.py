"""Admission control over the continuous-batching engine's fixed slots.

Three gates sit between ``submit`` and a slot (the "Staleness-Learning
Rate Scaling Laws" prescription: enforce the staleness budget in the
scheduler instead of hoping the queue stays shallow):

* **priority classes** — a binary heap keyed on (priority, arrival), so
  urgent traffic (e.g. the trainer's on-policy refresh batch) overtakes
  bulk rollouts; under sustained backpressure, waiting non-urgent
  requests *age*: after ``age_promote_s`` at the gate they are promoted
  to priority 0 so bulk traffic is never starved forever;
* **backpressure** — when the downstream ``RolloutQueue`` is nearly full
  the trainer is the bottleneck, so generating more stale data is pure
  waste: non-urgent admits are held at ``backpressure_high`` and all
  admits at ``backpressure_full``;
* **staleness budget** — a request is never admitted once
  ``now_version - submit_version`` exceeds ``d_max`` (it is dropped, or
  resubmitted fresh by the control plane), and in-flight sequences whose
  oldest token stamp falls behind the budget are preempted, returning all
  their refcounted blocks.

Every drop carries a reason on the request (``staleness_budget``,
``max_preempts``; the SLO-aware subclass in ``repro.loadgen.slo`` adds
``slo_shed``), and every preemption a reason in ``preempt_reasons`` —
the control plane folds both into per-reason ``ServingMetrics`` counters.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

from repro.rollout.continuous import Request

# canonical drop reasons (surfaced as ServingMetrics.drops_<reason>)
DROP_REASONS = ("staleness_budget", "max_preempts", "slo_shed")


@dataclasses.dataclass
class SchedulerConfig:
    d_max: int = 4                   # staleness budget, in weight versions
    backpressure_high: float = 0.75  # queue depth fraction: hold prio > 0
    backpressure_full: float = 1.0   # queue depth fraction: hold everything
    preempt_action: str = "requeue"  # "requeue" (restart fresh) | "drop"
    max_preempts: int = 2            # requeue at most this many times
    # priority aging: a queued request with priority > 0 that has waited
    # this long (scheduler-clock seconds) is promoted to priority 0 — it
    # overtakes the backpressure_high hold and younger urgent arrivals,
    # so sustained backpressure can no longer starve bulk traffic.
    # inf = aging off (the pre-aging behavior).
    age_promote_s: float = math.inf


class AdmissionScheduler:
    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.config = config or SchedulerConfig()
        self._heap: List[Tuple[int, int, float, Request]] = []
        self._seq = 0
        self.dropped: List[Request] = []
        # slot -> reason for the slots returned by the last check_preempt
        self.preempt_reasons: Dict[int, str] = {}

    def __len__(self) -> int:
        return len(self._heap)

    def enqueue(self, req: Request, now_s: float = 0.0) -> None:
        heapq.heappush(self._heap, (req.priority, self._seq, now_s, req))
        self._seq += 1

    def _promote_aged(self, now_s: float) -> None:
        """Rebuild the heap with aged non-urgent entries at priority 0.

        O(n) when anything aged, a single scan otherwise; heaps here are
        request queues (hundreds), not token queues.
        """
        age = self.config.age_promote_s
        if not math.isfinite(age) or not self._heap:
            return
        fresh, aged = [], []
        for e in self._heap:
            (aged if e[0] > 0 and now_s - e[2] >= age else fresh).append(e)
        if not aged:
            return
        fresh.extend((0, seq, t_enq, req) for _, seq, t_enq, req in aged)
        heapq.heapify(fresh)
        self._heap = fresh

    def pop_admissible(self, now_version: int, *, engine,
                       queue_frac: float = 0.0, now_s: float = 0.0
                       ) -> Optional[Tuple[Request, float]]:
        """Best admissible request, or None.

        Requests already past the staleness budget are dropped on the spot
        (collected in ``self.dropped`` for the control plane's resubmit
        policy). Block availability is checked against the engine's
        prefix-cache-aware estimate, with cache eviction as the fallback
        before giving up.
        """
        cfg = self.config
        self._promote_aged(now_s)
        while self._heap:
            prio, _, t_enq, req = self._heap[0]
            if now_version - req.submit_version > cfg.d_max:
                heapq.heappop(self._heap)
                req.drop_reason = "staleness_budget"
                self.dropped.append(req)
                continue
            if queue_frac >= cfg.backpressure_full:
                return None
            if prio > 0 and queue_frac >= cfg.backpressure_high:
                return None
            needed = engine.blocks_needed(req.prompt, req.max_new)
            if needed > engine.allocator.n_free:
                cache = getattr(engine, "prefix_cache", None)
                shortfall = needed - engine.allocator.n_free
                # Only evict when eviction can actually cover the
                # shortfall: destroying cached prefixes for a request
                # that still can't be admitted is pure loss.
                if cache is None or cache.evictable_count() < shortfall:
                    return None
                cache.evict(shortfall)
                if needed > engine.allocator.n_free:
                    return None
            heapq.heappop(self._heap)
            return req, t_enq
        return None

    def check_preempt(self, slots: Dict[int, Optional[Request]],
                      now_version: int, *, now_s: float = 0.0,
                      free_slots: int = 0) -> List[int]:
        """Slots to preempt, with reasons in ``self.preempt_reasons``.

        The base policy preempts slots whose oldest token stamp exceeds
        the staleness budget; ``now_s``/``free_slots`` feed subclass
        policies (deadline-aware overload preemption in loadgen.slo).
        """
        out = []
        self.preempt_reasons = {}
        for slot, req in slots.items():
            if req is None:
                continue
            if now_version - req.min_version() > self.config.d_max:
                out.append(slot)
                self.preempt_reasons[slot] = "staleness_budget"
        return out

    def handle_preempted(self, req: Request, now_version: int,
                         now_s: float = 0.0) -> str:
        """Requeue (restarted fresh) or drop a preempted request.

        Returns the action taken. Requeued requests lose their generated
        tokens — their stamps are already over budget, so the KV and
        partial generation are unusable for training anyway.
        """
        req.preempt_count += 1
        if (self.config.preempt_action == "drop"
                or req.preempt_count > self.config.max_preempts):
            req.drop_reason = "max_preempts"
            self.dropped.append(req)
            return "drop"
        req.reset_generation()
        req.submit_version = now_version
        self.enqueue(req, now_s)
        return "requeue"

    def take_dropped(self) -> List[Request]:
        out, self.dropped = self.dropped, []
        return out
