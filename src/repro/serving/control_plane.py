"""The rollout control plane: scheduler + interrupts + prefix cache + metrics.

Sits between ``async_rl.orchestrator`` and ``rollout.continuous``:

    trainer ──publish──▶ WeightStore ──interrupt──▶ ServingControlPlane
                                                        │  admit / preempt
                                                        ▼
                                            ContinuousBatchingEngine
                                                        │  finished Requests
                                                        ▼
                              RolloutBatch (per-token logp + version stamps)

Each ``step()``: poll the store (in-flight sequences resume under freshly
published weights, keeping their paged KV), preempt anything past the
staleness budget, admit from the priority queue through the radix prefix
cache, run one decode step, and fold everything into metrics.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

import jax.numpy as jnp

from repro.async_rl.buffer import RolloutQueue
from repro.async_rl.weights import WeightStore
from repro.data import tokenizer as tok
from repro.obs.tracing import flow_end, instant, span
from repro.rollout.continuous import ContinuousBatchingEngine, Request
from repro.rollout.engine import RolloutBatch
from repro.serving.interrupts import InterruptController
from repro.serving.metrics import ServingMetrics
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.scheduler import AdmissionScheduler, SchedulerConfig


class ServingControlPlane:
    def __init__(self, engine: ContinuousBatchingEngine, store: WeightStore,
                 scheduler: Optional[AdmissionScheduler] = None,
                 metrics: Optional[ServingMetrics] = None,
                 rollout_queue: Optional[RolloutQueue] = None,
                 use_prefix_cache: bool = True,
                 resubmit_dropped: bool = True,
                 prefill_budget: int = 2,
                 clock: Optional[Callable[[], float]] = None,
                 faults=None):
        self.engine = engine
        self.store = store
        # seeded fault plane (repro.resilience.FaultPlan): kv_exhaust
        # holds free KV blocks hostage, nan_logits poisons a decode row
        self.faults = faults
        self._kv_holds: List[int] = []
        # request-lifecycle clock: wall time by default; the loadgen
        # replay harness injects a virtual clock so submit/admit/TTFT/done
        # stamps (and hence SLO decisions) are trace-deterministic.
        # Perf telemetry (decode_time_s etc.) always uses wall time.
        self.clock = clock if clock is not None else time.perf_counter
        # prefill lane: at most this many chunk launches per step (horizon
        # boundary), so admissions stream in without a long prompt ever
        # stalling the decode lane for its whole prefill
        self.prefill_budget = prefill_budget
        # explicit None check: an empty AdmissionScheduler is falsy (len 0)
        self.scheduler = AdmissionScheduler(SchedulerConfig()) \
            if scheduler is None else scheduler
        self.metrics = ServingMetrics() if metrics is None else metrics
        self.rollout_queue = rollout_queue
        self.interrupts = InterruptController(store)
        self.resubmit_dropped = resubmit_dropped
        # SSM/hybrid engines carry recurrent state that cannot be shared
        # across sequences, so they opt out of the radix cache entirely
        if use_prefix_cache and engine.prefix_cache is None \
                and getattr(engine, "supports_prefix_cache", True):
            engine.prefix_cache = RadixPrefixCache(engine.allocator,
                                                   engine.state.block_size)
        self._rid = 0
        self._finished: Dict[int, Request] = {}
        self.dropped_requests: List[Request] = []
        self._last_seen_version = store.version

    # ------------------------------------------------------------- plumbing
    @property
    def n_inflight(self) -> int:
        return sum(1 for r in self.engine.slots.values() if r is not None)

    def _queue_frac(self) -> float:
        q = self.rollout_queue
        return q.depth_fraction if q is not None else 0.0

    # ------------------------------------------------------------- requests
    def submit(self, prompt, max_new: int = 16, priority: int = 0,
               tenant: str = "") -> int:
        self._rid += 1
        req = Request(self._rid, np.asarray(prompt), max_new,
                      priority=priority,
                      submit_version=self.store.version,
                      t_submit=self.clock(), tenant=tenant)
        self.scheduler.enqueue(req, req.t_submit)
        return self._rid

    # ----------------------------------------------------------------- step
    def step(self, key) -> List[Request]:
        with span("serve_step") as sp:
            return self._step(key, sp)

    def _step(self, key, sp) -> List[Request]:
        now = self.clock()
        inflight = self.n_inflight
        params, version, interrupted = self.interrupts.poll(inflight)
        if version != self._last_seen_version:
            # close the publish->resume flow arrow: this serving step is
            # the first to decode under the freshly published weights
            # (whether or not work was in flight when the publish landed)
            flow_end("publish", version, resumed=inflight)
            self._last_seen_version = version
        if interrupted and inflight:
            self.metrics.interrupts += 1
            self.metrics.resumed_sequences += inflight
            sp.set(resumed_under_version=version, resumed=inflight)
        if self.faults is not None:
            self._fault_hooks()

        # preemption of in-flight work: staleness budget (base scheduler)
        # and SLO-overload eviction (loadgen.slo scheduler), with the
        # reason counted per class of decision
        preempt_slots = self.scheduler.check_preempt(
            self.engine.slots, version, now_s=now,
            free_slots=len(self.engine.free_slots()))
        for slot in preempt_slots:
            req = self.engine.release_slot(slot)
            reason = self.scheduler.preempt_reasons.get(
                slot, "staleness_budget")
            self.metrics.preemptions += 1
            if reason == "slo_overload":
                self.metrics.preemptions_slo += 1
            else:
                self.metrics.preemptions_staleness += 1
            self.scheduler.handle_preempted(req, version, now)

        # admission through the priority + backpressure + budget gates
        queue_frac = self._queue_frac()
        for slot in self.engine.free_slots():
            picked = self.scheduler.pop_admissible(
                version, engine=self.engine, queue_frac=queue_frac,
                now_s=now)
            if picked is None:
                break
            req, t_enq = picked
            req.t_admit = now
            # chunked engines only map pages here; the prefill lane below
            # streams the compute under the per-step chunk budget
            self.engine.admit_request(params, slot, req, version=version,
                                      prefill=False)
            self.metrics.observe_request(
                prompt_tokens=len(req.prompt),
                prefix_hit=req.prefix_hit_tokens,
                queue_delay_s=max(now - t_enq, 0.0))

        # dropped queued requests: resubmit fresh, or surface. SLO sheds
        # are never resubmitted — the deadline they already missed does
        # not reset, so a resubmit would shed again immediately.
        for req in self.scheduler.take_dropped():
            reason = req.drop_reason or "staleness_budget"
            self.metrics.drops += 1
            if reason == "staleness_budget":
                self.metrics.drops_staleness_budget += 1
            elif reason == "max_preempts":
                self.metrics.drops_max_preempts += 1
            elif reason == "slo_shed":
                self.metrics.drops_slo_shed += 1
            if self.resubmit_dropped and reason != "slo_shed":
                # fresh lease: discard any partial generation (its stamps
                # are over budget and its tokens never see the new KV) and
                # restart from the prompt. Churn is self-limiting: versions
                # only advance while the trainer is fed, so a starved
                # trainer stops publishing and the restarts complete.
                req.reset_generation()
                req.preempt_count = 0
                req.drop_reason = ""
                req.submit_version = version
                self.scheduler.enqueue(req, now)
            else:
                req.t_done = now
                self.dropped_requests.append(req)

        # prefill lane: stream up to prefill_budget chunk launches over
        # mid-prefill slots. Slots whose prompt completes here enter the
        # decode lane in this same step (first token with zero extra
        # latency); longer prompts carry their cursor to the next
        # boundary while the decode lane below keeps emitting.
        if self.engine.prefilling_slots():
            t0 = time.perf_counter()
            launched = self.engine.prefill_step(
                params, version=version, max_chunks=self.prefill_budget)
            self.metrics.prefill_time_s += time.perf_counter() - t0
            self.metrics.prefill_chunks += launched
        self.metrics.prefill_compiles = self.engine.prefill_compiles

        # graceful degradation under KV-pool pressure: preflight the next
        # decode launch's block need and shed work through the scheduler
        # (requeue/drop policy included) instead of letting the allocator
        # hard-OOM mid-CoW-fork, which would desync the host mirrors.
        self._shed_for_blocks(version, now)

        finished: List[Request] = []
        if self.engine.decode_ready_slots():
            # one decode launch: a fused horizon (decode_horizon tokens per
            # slot, one host drain) or the per-token fallback. Admission,
            # preemption, interrupt polling, and prefill chunks above all
            # happen at this boundary — never inside the compiled loop.
            t0 = time.perf_counter()
            syncs0 = self.engine.host_syncs
            launches0 = self.engine.decode_launches
            if self.engine.decode_horizon > 1:
                finished = self.engine.step_horizon(params, key,
                                                    version=version)
            else:
                finished = self.engine.step(params, key, version=version)
            self.metrics.decode_time_s += time.perf_counter() - t0
            self.metrics.decode_tokens += self.engine.last_emitted
            # deltas, not lifetime counters: the engine may predate this
            # plane (warmup runs, shared engines)
            self.metrics.decode_host_syncs += \
                self.engine.host_syncs - syncs0
            self.metrics.decode_launches += \
                self.engine.decode_launches - launches0
            alloc = self.engine.allocator
            self.metrics.page_utilization.observe(
                1.0 - alloc.n_free / max(alloc.n_blocks, 1))
            self.metrics.cow_forks = alloc.forks
        # sequences that finished with non-finite logprobs (poisoned
        # logits / numerical blowup) are never emitted into rollout data —
        # they are discarded and resubmitted fresh under the live version
        if finished:
            finished = self._filter_nonfinite(finished, version, now)
        # time-to-first-token: stamp requests whose first sampled token
        # landed in this step's decode (finished ones already left their
        # slots, so scan both)
        t_now = self.clock()
        for r in list(self.engine.slots.values()) + finished:
            if r is not None and r.generated and r.t_first_token < 0.0:
                r.t_first_token = t_now
                if r.t_submit >= 0.0:
                    self.metrics.ttft_seconds.observe(
                        r.t_first_token - r.t_submit)
        for r in finished:
            r.t_done = t_now
        if finished:
            # per-span staleness attributes: distribution of the batch of
            # sequences that completed inside this serving step
            d_all = [version - v for r in finished
                     for v in r.token_versions]
            sp.set(finished=len(finished), version=version,
                   staleness_max=max(d_all, default=0),
                   staleness_mean=(sum(d_all) / len(d_all)
                                   if d_all else 0.0))
        for req in finished:
            self._finished[req.rid] = req
            self.metrics.observe_finished(
                staleness_values=[version - v for v in req.token_versions])
        return finished

    # ----------------------------------------------------------- resilience
    def _fault_hooks(self) -> None:
        """Per-step fault-plane sites (seeded chaos testing).

        ``kv_exhaust`` holds ``magnitude`` free KV blocks hostage while
        the spec fires (consecutive serving steps) and releases them when
        it stops — the shed path below must absorb the squeeze.
        ``nan_logits`` poisons one slot's row of the decode logits buffer;
        the non-finite filter must keep it out of the rollout data.
        """
        alloc = self.engine.allocator
        spec = self.faults.check("kv_exhaust")
        if spec is not None:
            want = max(int(spec.magnitude), 1)
            grab = min(want - len(self._kv_holds), alloc.n_free)
            if grab > 0:
                self._kv_holds.extend(alloc.alloc(grab))
                instant("kv_exhaust_hold", held=len(self._kv_holds))
        elif self._kv_holds:
            alloc.release(self._kv_holds)
            instant("kv_exhaust_release", released=len(self._kv_holds))
            self._kv_holds = []
        spec = self.faults.check("nan_logits")
        if spec is not None:
            row = int(self.faults.rng.integers(
                self.engine._next_logits.shape[0]))
            self.engine._next_logits = \
                self.engine._next_logits.at[row].set(jnp.nan)

    def _shed_for_blocks(self, version: int, now: float) -> None:
        """Shed decode-ready work until the next launch fits in the pool.

        Victims are the lowest priority class first (largest numeric
        priority), least decode progress within a class (cheapest to
        redo). The scheduler's preemption policy decides requeue vs drop.
        Never sheds the last sequence — headroom reclaim handles it.
        """
        shortfall = self.engine.decode_block_shortfall()
        while shortfall > 0:
            ready = self.engine.decode_ready_slots()
            if len(ready) <= 1:
                break
            victim = max(ready, key=lambda s: (
                self.engine.slots[s].priority,
                -len(self.engine.slots[s].generated)))
            req = self.engine.release_slot(victim)
            self.metrics.oom_sheds += 1
            instant("oom_shed", rid=req.rid, shortfall=shortfall)
            self.scheduler.handle_preempted(req, version, now)
            shortfall = self.engine.decode_block_shortfall()

    def _filter_nonfinite(self, finished: List[Request], version: int,
                          now: float) -> List[Request]:
        clean: List[Request] = []
        for req in finished:
            if np.isfinite(np.asarray(req.gen_logp, np.float64)).all():
                clean.append(req)
                continue
            self.metrics.nan_drops += 1
            instant("nan_drop", rid=req.rid)
            req.reset_generation()
            req.preempt_count = 0
            req.drop_reason = ""
            req.submit_version = version
            self.scheduler.enqueue(req, now)
        return clean

    # ------------------------------------------------------------ batch api
    def generate_batch(self, prompts: np.ndarray,
                       prompt_lengths: np.ndarray, key, max_new: int,
                       priority: int = 0, max_steps: int = 10_000
                       ) -> RolloutBatch:
        """Submit a (padded, ragged) prompt batch; drive steps to completion.

        The drop-in replacement for ``RolloutEngine.generate`` in the async
        loop — but weight publishes landing mid-batch are *absorbed*
        (sequences resume, stamps record the boundary) instead of being
        serialized against generation.
        """
        B = prompts.shape[0]
        with span("serve_generate", batch=B, max_new=max_new):
            return self._generate_batch(prompts, prompt_lengths, key,
                                        max_new, priority, max_steps)

    def _generate_batch(self, prompts, prompt_lengths, key, max_new: int,
                        priority: int, max_steps: int) -> RolloutBatch:
        B = prompts.shape[0]
        rids = []
        for i in range(B):
            L = int(prompt_lengths[i])
            rids.append(self.submit(prompts[i, :L], max_new,
                                    priority=priority))
        pending = set(rids)
        steps = idle = 0
        while pending:
            key, sub = jax.random.split(key)
            finished = self.step(sub)
            for req in finished:
                pending.discard(req.rid)
            # non-resubmitted drops never finish; account for them
            if not self.resubmit_dropped:
                pending -= {r.rid for r in self.dropped_requests}
            if not finished and self.n_inflight == 0:
                # admission held (backpressure / staleness budget) with
                # nothing decoding: idle-wait instead of burning max_steps
                idle += 1
                if idle > 20_000:
                    raise RuntimeError(
                        "control plane idle-stalled: admission held with "
                        "no work in flight (backpressure never released?)")
                time.sleep(0.005)
                continue
            idle = 0
            steps += 1
            if steps > max_steps:
                raise RuntimeError("control plane exceeded max_steps")
        reqs = [self._finished.pop(rid) for rid in rids
                if rid in self._finished]
        return self.rollout_batch(reqs, prompts.shape[1], max_new)

    def rollout_batch(self, reqs: List[Request], prompt_pad: int,
                      max_new: int) -> RolloutBatch:
        """Assemble finished requests into a stamped ``RolloutBatch``."""
        B = len(reqs)
        tokens = np.full((B, prompt_pad + max_new), tok.PAD, np.int32)
        lengths = np.zeros((B,), np.int32)
        gen_logp = np.zeros((B, max_new), np.float32)
        gen_mask = np.zeros((B, max_new), np.float32)
        gen_versions = np.zeros((B, max_new), np.int32)
        for i, r in enumerate(reqs):
            L = len(r.prompt)
            n = len(r.generated)
            lengths[i] = L
            tokens[i, :L] = r.prompt
            tokens[i, L: L + n] = r.generated
            gen_logp[i, :n] = r.gen_logp
            gen_mask[i, :n] = 1.0
            gen_versions[i, :n] = r.token_versions
            gen_versions[i, n:] = (r.token_versions[-1] if n
                                   else r.submit_version)
        version = int(gen_versions[gen_mask > 0].min()) \
            if B and gen_mask.any() else self.store.version
        return RolloutBatch(tokens=tokens, prompt_lengths=lengths,
                            gen_logp=gen_logp, gen_mask=gen_mask,
                            version=version, gen_versions=gen_versions)
