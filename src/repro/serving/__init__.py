"""Staleness-aware rollout control plane (scheduler / interrupts /
prefix cache / metrics) between the async orchestrator and the
continuous-batching engine."""
from repro.serving.control_plane import ServingControlPlane
from repro.serving.interrupts import InterruptController, InterruptEvent
from repro.serving.metrics import Histogram, ServingMetrics
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.scheduler import AdmissionScheduler, SchedulerConfig

__all__ = [
    "AdmissionScheduler",
    "Histogram",
    "InterruptController",
    "InterruptEvent",
    "RadixPrefixCache",
    "SchedulerConfig",
    "ServingControlPlane",
    "ServingMetrics",
]
