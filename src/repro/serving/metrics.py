"""Serving-side observability: a thin facade over the obs metrics registry.

The counters + histograms themselves now live in ``repro.obs.metrics``
(the process-wide registry the training engine and benchmarks also
publish into). ``ServingMetrics`` keeps its original mutable-dataclass
surface — every control-plane call site (``metrics.interrupts += 1``,
``metrics.staleness.observe(d)``, ...) is unchanged — but on construction
it registers its histograms and callback gauges for its scalar fields
under the ``serving_*`` namespace, so ``obs.get_registry().snapshot()``
and the prometheus dump see live serving state.

``ServingMetrics.snapshot()`` still flattens into the plain dict the
orchestrator attaches to ``StepRecord.serving`` — same keys as ever; the
histogram quantile estimates now interpolate within the winning bucket
(see ``obs.metrics.Histogram``).

Everything here is host-side and allocation-free on the hot path (fixed
bucket arrays, float adds).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.obs.metrics import Histogram, get_registry

__all__ = ["Histogram", "ServingMetrics"]


def _staleness_hist() -> Histogram:
    return Histogram((0, 1, 2, 4, 8, 16, 32))


def _delay_hist() -> Histogram:
    return Histogram((0.001, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 30.0))


def _util_hist() -> Histogram:
    return Histogram((0.1, 0.25, 0.5, 0.75, 0.9, 1.0))


def _ttft_hist() -> Histogram:
    return Histogram((0.001, 0.005, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
                      5.0, 30.0))


# scalar fields mirrored into the registry as callback gauges
_SCALAR_FIELDS = (
    "prefix_hit_tokens", "prefix_prompt_tokens", "prefill_tokens_computed",
    "prefill_chunks", "prefill_time_s",
    "prefill_compiles", "decode_tokens", "decode_host_syncs",
    "decode_launches", "decode_time_s", "interrupts", "resumed_sequences",
    "preemptions", "preemptions_staleness", "preemptions_slo",
    "drops", "drops_staleness_budget", "drops_max_preempts",
    "drops_slo_shed", "admitted", "completed", "cow_forks",
    "oom_sheds", "nan_drops",
)
_DERIVED_FIELDS = ("prefix_hit_rate", "host_syncs_per_token",
                   "decode_tokens_per_s", "prefill_tokens_per_s")


@dataclasses.dataclass
class ServingMetrics:
    """Control-plane counters; one instance per ServingControlPlane.

    A fresh instance re-registers the ``serving_*`` names (latest control
    plane wins — the registry reflects the live serving engine).
    """

    staleness: Histogram = dataclasses.field(default_factory=_staleness_hist)
    queue_delay_s: Histogram = dataclasses.field(default_factory=_delay_hist)
    page_utilization: Histogram = dataclasses.field(
        default_factory=_util_hist)
    # time-to-first-token: submit -> first sampled token, per request
    ttft_seconds: Histogram = dataclasses.field(default_factory=_ttft_hist)
    prefix_hit_tokens: int = 0
    prefix_prompt_tokens: int = 0
    prefill_tokens_computed: int = 0
    # prefill-lane telemetry: chunk launches streamed by the control
    # plane, wall time inside them, and distinct compile shapes
    # (bucket-ladder effectiveness: should stay ~#buckets, not ~#lengths)
    prefill_chunks: int = 0
    prefill_time_s: float = 0.0
    prefill_compiles: int = 0
    decode_tokens: int = 0
    # fused-horizon serving telemetry: blocking device->host drains on the
    # decode path, compiled decode launches (one per horizon), and wall
    # time spent decoding — host_syncs/token ~2 for the per-token loop,
    # <= 1/decode_launch (i.e. 1 per horizon) for the fused path.
    decode_host_syncs: int = 0
    decode_launches: int = 0
    decode_time_s: float = 0.0
    interrupts: int = 0          # weight publishes observed with work in flight
    resumed_sequences: int = 0   # in-flight seqs carried across a publish
    preemptions: int = 0
    # preemption reasons: staleness budget blown in-flight vs SLO-driven
    # overload eviction of a lower class (loadgen.slo scheduler)
    preemptions_staleness: int = 0
    preemptions_slo: int = 0
    drops: int = 0               # total, all reasons
    # drop reasons (scheduler stamps Request.drop_reason):
    drops_staleness_budget: int = 0  # queued past d_max
    drops_max_preempts: int = 0      # preempted once too often
    drops_slo_shed: int = 0          # deadline-aware admission shed
    admitted: int = 0
    completed: int = 0
    cow_forks: int = 0
    # resilience: sequences shed to keep the paged KV pool from hard-OOM
    # (preflight shortfall detection), and finished sequences discarded
    # for non-finite logprobs (NaN logits fault / numerical blowup)
    oom_sheds: int = 0
    nan_drops: int = 0
    register: dataclasses.InitVar[bool] = True

    def __post_init__(self, register: bool = True) -> None:
        if register:
            self.register_into(get_registry())

    def register_into(self, registry) -> None:
        """Expose this instance's state through a metrics registry:
        histograms are adopted as-is, scalar + derived fields become
        callback gauges reading the live attributes."""
        registry.register("serving_staleness", self.staleness)
        registry.register("serving_queue_delay_s", self.queue_delay_s)
        registry.register("serving_page_utilization", self.page_utilization)
        registry.register("serving_ttft_seconds", self.ttft_seconds)
        for f in _SCALAR_FIELDS + _DERIVED_FIELDS:
            registry.gauge(f"serving_{f}",
                           fn=(lambda self=self, f=f:
                               float(getattr(self, f))))

    @property
    def prefix_hit_rate(self) -> float:
        if not self.prefix_prompt_tokens:
            return 0.0
        return self.prefix_hit_tokens / self.prefix_prompt_tokens

    @property
    def host_syncs_per_token(self) -> float:
        return self.decode_host_syncs / max(self.decode_tokens, 1)

    @property
    def decode_tokens_per_s(self) -> float:
        if self.decode_time_s <= 0.0:
            return 0.0
        return self.decode_tokens / self.decode_time_s

    @property
    def prefill_tokens_per_s(self) -> float:
        if self.prefill_time_s <= 0.0:
            return 0.0
        return self.prefill_tokens_computed / self.prefill_time_s

    def observe_request(self, *, prompt_tokens: int, prefix_hit: int,
                        queue_delay_s: float) -> None:
        self.admitted += 1
        self.prefix_prompt_tokens += prompt_tokens
        self.prefix_hit_tokens += prefix_hit
        self.prefill_tokens_computed += prompt_tokens - prefix_hit
        self.queue_delay_s.observe(queue_delay_s)

    def observe_finished(self, *, staleness_values) -> None:
        self.completed += 1
        for d in staleness_values:
            self.staleness.observe(float(d))

    def snapshot(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        out.update(self.staleness.snapshot("staleness"))
        out.update(self.queue_delay_s.snapshot("queue_delay_s"))
        out.update(self.page_utilization.snapshot("page_util"))
        out.update(self.ttft_seconds.snapshot("ttft_s"))
        out.update(
            prefix_hit_rate=self.prefix_hit_rate,
            prefix_hit_tokens=float(self.prefix_hit_tokens),
            prefill_tokens_computed=float(self.prefill_tokens_computed),
            prefill_chunks=float(self.prefill_chunks),
            prefill_time_s=self.prefill_time_s,
            prefill_compiles=float(self.prefill_compiles),
            prefill_tokens_per_s=self.prefill_tokens_per_s,
            decode_tokens=float(self.decode_tokens),
            decode_host_syncs=float(self.decode_host_syncs),
            decode_launches=float(self.decode_launches),
            decode_time_s=self.decode_time_s,
            host_syncs_per_token=self.host_syncs_per_token,
            decode_tokens_per_s=self.decode_tokens_per_s,
            interrupts=float(self.interrupts),
            resumed_sequences=float(self.resumed_sequences),
            preemptions=float(self.preemptions),
            preemptions_staleness=float(self.preemptions_staleness),
            preemptions_slo=float(self.preemptions_slo),
            drops=float(self.drops),
            drops_staleness_budget=float(self.drops_staleness_budget),
            drops_max_preempts=float(self.drops_max_preempts),
            drops_slo_shed=float(self.drops_slo_shed),
            admitted=float(self.admitted),
            completed=float(self.completed),
            cow_forks=float(self.cow_forks),
            oom_sheds=float(self.oom_sheds),
            nan_drops=float(self.nan_drops),
        )
        return out
