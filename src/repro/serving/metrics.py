"""Serving-side observability: counters + histograms for the control plane.

Everything here is host-side and allocation-free on the hot path (fixed
bucket arrays, float adds). ``ServingMetrics.snapshot()`` flattens into the
plain dict the orchestrator attaches to ``StepRecord.serving``, so the
staleness distribution, prefix-cache hit rate, queue delay, page
utilization, and interrupt counts ride along with every training step's
record.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence


class Histogram:
    """Fixed-bucket histogram (prometheus-style cumulative-free buckets)."""

    def __init__(self, bounds: Sequence[float]):
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # last = +inf overflow
        self.total = 0
        self.sum = 0.0
        self.max = 0.0

    def observe(self, x: float) -> None:
        i = 0
        for b in self.bounds:
            if x <= b:
                break
            i += 1
        self.counts[i] += 1
        self.total += 1
        self.sum += x
        self.max = max(self.max, x)

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-upper-bound quantile estimate (0 < q <= 1)."""
        if not self.total:
            return 0.0
        target = q * self.total
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return self.bounds[i] if i < len(self.bounds) else self.max
        return self.max

    def snapshot(self, prefix: str) -> Dict[str, float]:
        return {
            f"{prefix}_mean": self.mean,
            f"{prefix}_p50": self.quantile(0.5),
            f"{prefix}_p99": self.quantile(0.99),
            f"{prefix}_max": self.max,
            f"{prefix}_count": float(self.total),
        }


def _staleness_hist() -> Histogram:
    return Histogram((0, 1, 2, 4, 8, 16, 32))


def _delay_hist() -> Histogram:
    return Histogram((0.001, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 30.0))


def _util_hist() -> Histogram:
    return Histogram((0.1, 0.25, 0.5, 0.75, 0.9, 1.0))


@dataclasses.dataclass
class ServingMetrics:
    """Control-plane counters; one instance per ServingControlPlane."""

    staleness: Histogram = dataclasses.field(default_factory=_staleness_hist)
    queue_delay_s: Histogram = dataclasses.field(default_factory=_delay_hist)
    page_utilization: Histogram = dataclasses.field(
        default_factory=_util_hist)
    prefix_hit_tokens: int = 0
    prefix_prompt_tokens: int = 0
    prefill_tokens_computed: int = 0
    decode_tokens: int = 0
    # fused-horizon serving telemetry: blocking device->host drains on the
    # decode path, compiled decode launches (one per horizon), and wall
    # time spent decoding — host_syncs/token ~2 for the per-token loop,
    # <= 1/decode_launch (i.e. 1 per horizon) for the fused path.
    decode_host_syncs: int = 0
    decode_launches: int = 0
    decode_time_s: float = 0.0
    interrupts: int = 0          # weight publishes observed with work in flight
    resumed_sequences: int = 0   # in-flight seqs carried across a publish
    preemptions: int = 0
    drops: int = 0               # admission-refused, staleness budget blown
    admitted: int = 0
    completed: int = 0
    cow_forks: int = 0

    @property
    def prefix_hit_rate(self) -> float:
        if not self.prefix_prompt_tokens:
            return 0.0
        return self.prefix_hit_tokens / self.prefix_prompt_tokens

    @property
    def host_syncs_per_token(self) -> float:
        return self.decode_host_syncs / max(self.decode_tokens, 1)

    @property
    def decode_tokens_per_s(self) -> float:
        if self.decode_time_s <= 0.0:
            return 0.0
        return self.decode_tokens / self.decode_time_s

    def observe_request(self, *, prompt_tokens: int, prefix_hit: int,
                        queue_delay_s: float) -> None:
        self.admitted += 1
        self.prefix_prompt_tokens += prompt_tokens
        self.prefix_hit_tokens += prefix_hit
        self.prefill_tokens_computed += prompt_tokens - prefix_hit
        self.queue_delay_s.observe(queue_delay_s)

    def observe_finished(self, *, staleness_values) -> None:
        self.completed += 1
        for d in staleness_values:
            self.staleness.observe(float(d))

    def snapshot(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        out.update(self.staleness.snapshot("staleness"))
        out.update(self.queue_delay_s.snapshot("queue_delay_s"))
        out.update(self.page_utilization.snapshot("page_util"))
        out.update(
            prefix_hit_rate=self.prefix_hit_rate,
            prefix_hit_tokens=float(self.prefix_hit_tokens),
            prefill_tokens_computed=float(self.prefill_tokens_computed),
            decode_tokens=float(self.decode_tokens),
            decode_host_syncs=float(self.decode_host_syncs),
            decode_launches=float(self.decode_launches),
            decode_time_s=self.decode_time_s,
            host_syncs_per_token=self.host_syncs_per_token,
            decode_tokens_per_s=self.decode_tokens_per_s,
            interrupts=float(self.interrupts),
            resumed_sequences=float(self.resumed_sequences),
            preemptions=float(self.preemptions),
            drops=float(self.drops),
            admitted=float(self.admitted),
            completed=float(self.completed),
            cow_forks=float(self.cow_forks),
        )
        return out
