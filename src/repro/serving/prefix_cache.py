"""Radix-tree prefix cache over the paged KV pool (SGLang-style).

GRPO rollouts send the *same* prompt ``group_size`` times, and agentic
tasks re-send long shared system/tool prefixes; re-prefilling them is the
dominant wasted work in grouped RL serving. This cache maps token prefixes
to reference-counted blocks in ``rollout.paged_cache.BlockAllocator`` so a
prefix is prefilled once and then shared:

* nodes sit at block granularity — an edge holds the exact token tuple of
  one block (``block_size`` tokens for interior/full nodes, fewer for
  partial leaves);
* ``match`` walks the tree and *increfs* every returned block on behalf of
  the requesting sequence (the sequence's ``release`` decref pairs with
  it);
* shared blocks are never written in place — the engine's copy-on-write
  guard (``paged_cache.ensure_writable``) forks a private copy the moment
  a sequence's write position lands inside a block with refcount > 1;
* the cache itself holds one reference per registered block, so blocks
  survive their creating sequence and are reclaimed by LRU ``evict`` when
  the allocator runs dry.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.rollout.paged_cache import BlockAllocator

TokenKey = Tuple[int, ...]


class _Node:
    __slots__ = ("key", "block", "children", "partials", "parent",
                 "last_used")

    def __init__(self, key: TokenKey, block: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[TokenKey, "_Node"] = {}   # full-block edges
        self.partials: Dict[TokenKey, "_Node"] = {}   # partial leaf edges
        self.last_used = 0

    @property
    def is_leaf(self) -> bool:
        return not self.children and not self.partials


class RadixPrefixCache:
    def __init__(self, allocator: BlockAllocator, block_size: int):
        self.allocator = allocator
        self.block_size = block_size
        self.root = _Node((), -1, None)
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.evicted_blocks = 0

    # ------------------------------------------------------------ internals
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _walk(self, tokens, max_tokens: Optional[int]
              ) -> Tuple[_Node, List[_Node], int]:
        """Longest match. Returns (last node, matched chain, n_tokens)."""
        toks = [int(t) for t in tokens]
        if max_tokens is not None:
            toks = toks[:max(max_tokens, 0)]
        bs = self.block_size
        node = self.root
        chain: List[_Node] = []
        i = 0
        while i + bs <= len(toks):
            child = node.children.get(tuple(toks[i: i + bs]))
            if child is None:
                break
            chain.append(child)
            node = child
            i += bs
        # token-granular tail: the edge out of `node` with the longest
        # common prefix against the remaining tokens. Using only the first
        # j tokens of a cached block is sound — seq_lens masks the block's
        # extra KV, and the first divergent write copy-on-write-forks it.
        rem = tuple(toks[i:])
        best: Optional[_Node] = None
        best_j = 0
        for key, cand in list(node.children.items()) \
                + list(node.partials.items()):
            j = 0
            for a, b in zip(key, rem):
                if a != b:
                    break
                j += 1
            if j > best_j:
                best, best_j = cand, j
        if best is not None:
            chain.append(best)
            i += best_j
        return node, chain, i

    # ----------------------------------------------------------------- api
    def lookup(self, tokens, max_tokens: Optional[int] = None
               ) -> Tuple[int, int]:
        """(n_blocks, n_tokens) the prefix match would reuse. No incref."""
        _, chain, n = self._walk(tokens, max_tokens)
        return len(chain), n

    def match(self, tokens, max_tokens: Optional[int] = None
              ) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``; increfs matched blocks.

        Returns (blocks, n_matched_tokens). The caller owns one reference
        per returned block (released via the sequence's normal
        ``release_sequence`` path).
        """
        _, chain, n = self._walk(tokens, max_tokens)
        now = self._tick()
        for node in chain:
            self.allocator.incref(node.block)
            node.last_used = now
        if chain:
            self.hits += 1
        else:
            self.misses += 1
        return [node.block for node in chain], n

    def insert(self, tokens, blocks: List[int]) -> int:
        """Register a prefilled prompt's blocks; returns #new nodes.

        ``blocks[i]`` must hold the KV of tokens ``[i*bs, (i+1)*bs)`` (the
        final entry may be a partial block). Existing nodes are left in
        place — their block already carries the canonical KV — and each
        newly registered block gets one cache-owned reference.
        """
        bs = self.block_size
        toks = [int(t) for t in tokens]
        assert len(blocks) >= -(-len(toks) // bs), (len(toks), blocks)
        node = self.root
        now = self._tick()
        created = 0
        i = bi = 0
        while i + bs <= len(toks):
            chunk = tuple(toks[i: i + bs])
            child = node.children.get(chunk)
            if child is None:
                child = _Node(chunk, blocks[bi], node)
                self.allocator.incref(blocks[bi])
                node.children[chunk] = child
                created += 1
            child.last_used = now
            node = child
            i += bs
            bi += 1
        rem = tuple(toks[i:])
        if rem:
            leaf = node.partials.get(rem)
            if leaf is None:
                leaf = _Node(rem, blocks[bi], node)
                self.allocator.incref(blocks[bi])
                node.partials[rem] = leaf
                created += 1
            leaf.last_used = now
        return created

    # ------------------------------------------------------------- eviction
    def _evictable(self) -> List[_Node]:
        """Leaves only the cache still references (refcount == 1)."""
        out: List[_Node] = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            for child in list(node.children.values()):
                stack.append(child)
                if child.is_leaf and self.allocator.refs(child.block) == 1:
                    out.append(child)
            for leaf in node.partials.values():
                if self.allocator.refs(leaf.block) == 1:
                    out.append(leaf)
        return out

    def evictable_count(self) -> int:
        """Blocks repeated ``evict`` rounds could *ever* free.

        A node is reclaimable iff only the cache references its block
        (refcount == 1) AND its entire subtree is reclaimable — an
        in-use descendant pins every ancestor, since eviction only takes
        leaves. Admission uses this to decide whether evicting can
        possibly cover a shortfall before destroying any cached prefix.
        """
        def walk(node: _Node) -> Tuple[int, bool]:
            total, all_free = 0, True
            for child in list(node.children.values()) \
                    + list(node.partials.values()):
                t, f = walk(child)
                total += t
                all_free &= f
            if node is self.root:
                return total, all_free
            if all_free and self.allocator.refs(node.block) == 1:
                return total + 1, True
            return total, False

        return walk(self.root)[0]

    def _drop(self, node: _Node) -> None:
        parent = node.parent
        if node.key in parent.partials and parent.partials[node.key] is node:
            del parent.partials[node.key]
        elif node.key in parent.children \
                and parent.children[node.key] is node:
            del parent.children[node.key]
        self.allocator.decref(node.block)
        self.evicted_blocks += 1

    def evict(self, n_blocks: int) -> int:
        """LRU-evict up to ``n_blocks`` cache-only blocks; returns #freed.

        Dropping a leaf can expose its parent; rounds repeat until the
        target is met or nothing is evictable.
        """
        freed = 0
        while freed < n_blocks:
            candidates = self._evictable()
            if not candidates:
                break
            candidates.sort(key=lambda nd: nd.last_used)
            for node in candidates:
                self._drop(node)
                freed += 1
                if freed >= n_blocks:
                    break
        return freed

    def clear(self) -> int:
        """Drop every cache-held reference (blocks in use survive)."""
        dropped = 0
        stack = list(self.root.children.values()) \
            + list(self.root.partials.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            stack.extend(node.partials.values())
            self.allocator.decref(node.block)
            dropped += 1
        self.root = _Node((), -1, None)
        return dropped

    @property
    def n_cached_blocks(self) -> int:
        count = 0
        stack = list(self.root.children.values()) \
            + list(self.root.partials.values())
        while stack:
            node = stack.pop()
            count += 1
            stack.extend(node.children.values())
            stack.extend(node.partials.values())
        return count
