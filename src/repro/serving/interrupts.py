"""Interruptible generation: weight publishes land mid-decode.

The rollout engines the paper builds on (AReaL-style) either drain
in-flight requests before swapping weights (head-of-line blocking) or
restart them (wasted prefill). The control plane does neither: on
``WeightStore.publish`` the in-flight sequences *keep their paged KV* and
simply continue decoding under the new params — the per-token version
stamps recorded by ``ContinuousBatchingEngine.step`` mark exactly where
the behavior policy changed, which is what turns ``a3po.staleness`` from a
per-sequence scalar into an honest ``[B, T]`` signal.

``InterruptController`` is the bridge: it subscribes to the store, and the
serving loop calls ``poll()`` once per step to pick up the freshest
(params, version) plus an ``interrupted`` edge flag for metrics.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, List, Tuple

from repro.async_rl.weights import WeightStore


@dataclasses.dataclass
class InterruptEvent:
    """One weight publish observed by the serving loop."""

    old_version: int
    new_version: int
    inflight: int   # sequences that resumed under the new params


class InterruptController:
    def __init__(self, store: WeightStore):
        self._store = store
        self._published = threading.Event()
        subscribe = getattr(store, "subscribe", None)
        if subscribe is not None:
            subscribe(self._on_publish)
        self._seen_version = store.version
        self.events: List[InterruptEvent] = []

    def _on_publish(self, version: int) -> None:
        self._published.set()

    def poll(self, inflight: int = 0) -> Tuple[Any, int, bool]:
        """Latest (params, version, interrupted-edge).

        ``interrupted`` is True exactly once per observed publish; when
        ``inflight`` > 0 the event is recorded (those sequences resume
        under the new params instead of being drained or restarted).
        """
        params, version = self._store.latest()
        changed = version != self._seen_version
        interrupted = changed or self._published.is_set()
        self._published.clear()
        if changed:
            self.events.append(InterruptEvent(self._seen_version, version,
                                              inflight))
            self._seen_version = version
        return params, version, interrupted

    @property
    def n_interrupts(self) -> int:
        return len(self.events)
