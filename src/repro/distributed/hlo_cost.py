"""Trip-count-aware cost extraction from compiled (SPMD-partitioned) HLO.

Why: XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE.
Our programs scan over layers (and microbatches, query chunks, SSD chunks),
so module-level numbers undercount by the product of trip counts — 60-200x
for deep models. This module parses the optimized HLO text, reconstructs
the computation call graph with while trip counts, and accumulates:

  * flops             — dot/convolution ops, scaled by enclosing trips
  * traffic bytes     — operand+output bytes of non-fusion-internal ops
                        (post-fusion HLO: a fusion's boundary IS the HBM
                        traffic), scaled by trips
  * collective bytes  — all-gather/all-reduce/reduce-scatter/all-to-all/
                        collective-permute output bytes, scaled by trips

Numbers are per-device (the partitioned module is per-device).
Trip counts come from the ``constant(N)`` compared against the induction
variable in each while condition — exact for lax.scan-generated loops.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"\s*%?([\w\.\-]+)")
_ALL_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=)\s*%?([\w\.\-]+)"
    r"|branch_computations=\{([^}]*)\}")


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = tuple(int(d) for d in m.group(2).split(",")) \
            if m.group(2) else ()
        out.append((m.group(1), dims))
    return out


def _shape_bytes(shapes) -> int:
    total = 0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


@dataclass
class Op:
    name: str
    kind: str
    out_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = field(
        default_factory=dict)
    is_fusion_body: bool = False


def _comp_header(line: str) -> Optional[Tuple[str, bool]]:
    """Computation headers: ``[ENTRY] %name (args...) -> type {``."""
    s = line.strip()
    if not s.endswith("{") or " -> " not in s:
        return None
    is_entry = s.startswith("ENTRY")
    if is_entry:
        s = s[len("ENTRY"):].strip()
    m = re.match(r"%?([\w\.\-]+)\s*\(", s)
    if not m or "=" in s.split("(")[0]:
        return None
    return m.group(1), is_entry
_OP_SPLIT = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\d]+"
    r"(?:\{[^}]*\})?)+)\s+([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            hdr = _comp_header(line)
            if hdr:
                current = Computation(hdr[0])
                if hdr[1]:
                    entry_name = hdr[0]
                comps[current.name] = current
            continue
        if line.strip() == "}" or line.strip().startswith("} "):
            current = None
            continue
        m = _OP_SPLIT.match(line)
        if not m:
            continue
        name, type_str, kind, rest = m.groups()
        out_shapes = _parse_shapes(type_str)
        # operands: %refs inside the parens before any attr keywords
        paren_part = rest.split("),")[0] if ")," in rest else rest
        operands = _OPERAND_RE.findall(paren_part)
        op = Op(name, kind, out_shapes, operands, rest, line)
        current.ops.append(op)
        current.symbols[name] = out_shapes
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(while_op: Op, cond: Optional[Computation]) -> int:
    """Prefer XLA's known_trip_count; fall back to the cond constant."""
    m = re.search(r'known_trip_count..:..n.:.(\d+)', while_op.attrs)
    if m:
        return int(m.group(1))
    consts = []
    if cond is not None:
        for op in cond.ops:
            if op.kind == "constant":
                mm = re.search(r"constant\((\d+)\)", op.line)
                if mm:
                    consts.append(int(mm.group(1)))
    return max(consts) if consts else 1


def _called(op: Op) -> List[str]:
    names: List[str] = []
    for m in _ALL_CALLED_RE.finditer(op.attrs):
        if m.group(1):
            names.append(m.group(1))
        elif m.group(2):
            names.extend(re.findall(r"%?([\w\.\-]+)", m.group(2)))
    return names


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for _, dims in op.out_shapes:
        for d in dims:
            out_elems *= d
    # contraction size from lhs operand shape + contracting dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    k = 1
    if m and op.operands:
        lhs = comp.symbols.get(op.operands[0])
        if lhs:
            dims = lhs[0][1]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(dims):
                    k *= dims[idx]
    # batch dims are part of out_elems already
    return 2.0 * out_elems * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for _, dims in op.out_shapes:
        for d in dims:
            out_elems *= d
    if len(op.operands) >= 2:
        rhs = comp.symbols.get(op.operands[1])
        if rhs:
            k = 1
            for d in rhs[0][1]:
                k *= d
            # rhs = [spatial..., in_ch, out_ch]; per-output work ~ rhs/out_ch
            out_ch = rhs[0][1][-1] if rhs[0][1] else 1
            return 2.0 * out_elems * (k / max(out_ch, 1))
    return 2.0 * out_elems


@dataclass
class HloCost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_ops: Dict[str, Dict[str, float]] = field(default_factory=dict)
    while_trips: Dict[str, int] = field(default_factory=dict)


def analyze(text: str) -> HloCost:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    cost = HloCost()
    if entry is None:
        return cost
    seen_stack: List[str] = []

    def walk(comp: Computation, scale: float, in_fusion: bool) -> None:
        if comp.name in seen_stack:  # guard cycles
            return
        seen_stack.append(comp.name)
        for op in comp.ops:
            if op.kind == "dot":
                cost.flops += scale * _dot_flops(op, comp)
            elif op.kind == "convolution":
                cost.flops += scale * _conv_flops(op, comp)
            is_coll = any(op.kind.startswith(c) for c in COLLECTIVES)
            if is_coll and not op.kind.endswith("-done"):
                base = op.kind.replace("-start", "")
                b = scale * _shape_bytes(op.out_shapes)
                d = cost.collective_ops.setdefault(
                    base, {"count": 0, "bytes": 0.0})
                d["count"] += scale
                d["bytes"] += b
                cost.collective_bytes += b
            # memory traffic: boundary ops only (not inside fusions)
            if not in_fusion and op.kind not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast"):
                out_b = _shape_bytes(op.out_shapes)
                in_b = sum(_shape_bytes(comp.symbols[o])
                           for o in op.operands if o in comp.symbols)
                cost.traffic_bytes += scale * (out_b + in_b)
            # recurse
            if op.kind == "while":
                m_body = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                m_cond = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                body = comps.get(m_body.group(1)) if m_body else None
                cond = comps.get(m_cond.group(1)) if m_cond else None
                trips = _trip_count(op, cond)
                cost.while_trips[op.name] = trips
                if body:
                    walk(body, scale * trips, in_fusion)
            elif op.kind == "fusion":
                for c in _called(op):
                    if c in comps:
                        walk(comps[c], scale, True)
            elif op.kind in ("call", "conditional", "custom-call",
                             "reduce", "sort", "scatter", "map",
                             "reduce-window", "select-and-scatter",
                             "all-reduce", "reduce-scatter"):
                for c in _called(op):
                    if c in comps:
                        walk(comps[c], scale, True)
        seen_stack.pop()

    walk(entry, 1.0, False)
    return cost
