"""HLO parsing for the roofline: collective bytes + op census.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic, so we parse the (SPMD-partitioned, per-device) HLO text and sum
the output-shape bytes of every collective op. ``*-start`` async forms are
counted once (their ``*-done`` pair is skipped).
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %all-reduce.5 = bf16[128,1024]{1,0} all-reduce(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(?:\()?\s*((?:\w+\[[\d,]*\](?:\{[^}]*\})?(?:,\s*)?)+)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shapes_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Tuple[int, Dict[str, Dict[str, int]]]:
    """Returns (total_bytes, {op: {count, bytes}}) from per-device HLO."""
    per_op: Dict[str, Dict[str, int]] = {
        op: {"count": 0, "bytes": 0} for op in COLLECTIVES}
    total = 0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _LINE_RE.search(line)
        if not m:
            continue
        shapes_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shapes_str)
        per_op[op]["count"] += 1
        per_op[op]["bytes"] += b
        total += b
    return total, {k: v for k, v in per_op.items() if v["count"]}


# ------------------------------------------------------------------ roofline
# TPU v5e hardware constants (per system prompt)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s per chip
HBM_BW = 819e9                 # B/s per chip
ICI_BW = 50e9                  # B/s per link


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float) -> Dict[str, float]:
    """Three roofline terms in seconds (per device / chip)."""
    compute = flops_per_device / PEAK_FLOPS_BF16
    memory = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant  # type: ignore[assignment]
    return terms
