from repro.distributed.sharding import (  # noqa: F401
    DEFAULT_RULES,
    ShardingEnv,
    constrain,
    current_env,
    use_sharding,
)
