"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Weights and activations are annotated with *logical* axis names; this module
maps them onto whatever mesh is active. Rules degrade gracefully: if a
tensor dimension is not divisible by its mesh axis (e.g. kv_heads=8 on a
model=16 axis) the dimension is replicated instead of failing, which is
exactly what a production system must do across heterogeneous architectures.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRule = Tuple[str, Union[str, Tuple[str, ...], None]]


def abstract_mesh(axis_sizes: Sequence[int],
                  axis_names: Sequence[str]) -> "jax.sharding.AbstractMesh":
    """Version-portable ``AbstractMesh`` constructor.

    jax <= 0.4.x takes a tuple of ``(name, size)`` pairs; newer releases
    take ``(axis_sizes, axis_names)``. Feeding the new calling convention
    to the old constructor leaves the mesh shape as a bare int, which is
    the ``TypeError: 'int' object is not iterable`` failure mode — so we
    normalize here instead of at every call site.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))

# Default logical->mesh mapping. "embed" is the FSDP axis (weight d_model
# dims sharded over data); activations use "act_embed" which is never
# sharded over data.
DEFAULT_RULES: Tuple[AxisRule, ...] = (
    ("batch", ("pod", "data")),
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("ff", "model"),
    ("experts", "model"),
    ("expert_ff", None),
    ("ssm_inner", "model"),
    ("ssm_heads", "model"),
    ("mla_rank", None),
    ("embed", "data"),      # FSDP weight sharding
    ("act_embed", None),
    ("act_heads", "model"),
    ("act_ff", "model"),
    ("seq", None),
    ("seq_sp", None),  # sequence-parallel residual stream (opt-in: "model")
    ("kv_seq", None),
    ("layers", None),
    ("head_dim", None),
    ("ssm_state", None),
    ("conv", None),
    ("capacity", None),
)


class ShardingEnv:
    """A mesh + rule set, resolving logical axes to concrete shardings."""

    def __init__(self, mesh: Mesh, rules: Sequence[AxisRule] = DEFAULT_RULES,
                 fsdp: bool = True, tp_fallback: bool = False):
        self.mesh = mesh
        self.rules: Dict[str, Union[str, Tuple[str, ...], None]] = dict(rules)
        self.fsdp = fsdp
        # tp_fallback: if a weight leaves the "model" axis unused (e.g.
        # heads=56 on model=16), shard its d_model ("embed") axis over
        # "model" instead — row-parallel TP with an extra activation
        # all-reduce, instead of full weight replication.
        self.tp_fallback = tp_fallback

    def _mesh_axes_for(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        target = self.rules.get(logical, None)
        if target is None:
            return ()
        if logical == "embed" and not self.fsdp:
            return ()
        if isinstance(target, str):
            target = (target,)
        return tuple(a for a in target if a in self.mesh.axis_names)

    def spec(self, shape: Sequence[int],
             logical_axes: Sequence[Optional[str]]) -> P:
        """PartitionSpec for ``shape`` under the rules, divisibility-aware."""
        assert len(shape) == len(logical_axes), (shape, logical_axes)
        used: set = set()
        parts = []
        for dim, name in zip(shape, logical_axes):
            axes = self._mesh_axes_for(name)
            axes = tuple(a for a in axes if a not in used)
            size = int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1
            if axes and dim % size == 0 and dim >= size:
                used.update(axes)
                parts.append(axes if len(axes) > 1 else axes[0])
            else:
                parts.append(None)
        if (self.tp_fallback and "model" in self.mesh.axis_names
                and "model" not in used):
            msize = self.mesh.shape["model"]
            for i, (dim, name) in enumerate(zip(shape, logical_axes)):
                if (name == "embed" and parts[i] is None
                        and dim % msize == 0 and dim >= msize):
                    parts[i] = "model"
                    break
        # trim trailing Nones for tidier HLO
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding(self, shape: Sequence[int],
                 logical_axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, logical_axes))


_LOCAL = threading.local()


def current_env() -> Optional[ShardingEnv]:
    return getattr(_LOCAL, "env", None)


@contextlib.contextmanager
def use_sharding(env: Optional[ShardingEnv]):
    prev = current_env()
    _LOCAL.env = env
    try:
        yield env
    finally:
        _LOCAL.env = prev


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical names; no-op outside a mesh."""
    env = current_env()
    if env is None or np.prod(list(env.mesh.shape.values())) == 1:
        return x
    spec = env.spec(x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(env.mesh, spec))
