"""Unified observability for the async-RL loop.

Three pillars, one import surface:

* ``obs.tracing`` — a low-overhead span tracer (``span(...)`` context
  manager / ``trace_span`` decorator, thread-aware, monotonic clocks)
  exporting Chrome/Perfetto ``trace.json``, with flow events tying a
  weight publish to the serving step that resumed under it.
* ``obs.metrics`` — a process-wide metrics registry (Counter / Gauge /
  Histogram with labels); ``serving.metrics.ServingMetrics`` is a thin
  facade over it and training-side metrics land in the same registry, so
  one ``registry.snapshot()`` serves the orchestrator, benchmarks, and
  tests.
* ``obs.runlog`` — a schema-versioned JSONL run log (one record per
  training step) behind the ``--log-jsonl``/``--quiet`` CLI surface.

``python -m repro.obs.report`` renders a run summary from the JSONL +
trace pair; ``python -m repro.obs.validate`` is the CI schema gate.
"""
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
)
from repro.obs.runlog import (
    RUNLOG_SCHEMA_VERSION,
    STEP_REQUIRED_KEYS,
    RunLogger,
    step_record_dict,
)
from repro.obs.tracing import (
    SpanTracer,
    annotate,
    flow_end,
    flow_start,
    get_tracer,
    install_tracer,
    span,
    trace_span,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "RUNLOG_SCHEMA_VERSION",
    "RunLogger",
    "STEP_REQUIRED_KEYS",
    "SpanTracer",
    "annotate",
    "flow_end",
    "flow_start",
    "get_registry",
    "get_tracer",
    "install_tracer",
    "span",
    "step_record_dict",
    "trace_span",
]
