"""Schema-versioned JSONL run log + uniform status output.

One ``kind="step"`` record per training step (the machine-readable twin
of the human stdout line), plus free-form ``kind="meta"`` / ``kind=...``
records for run headers and launcher events. The schema version rides in
every record so downstream consumers (``repro.obs.report``, the CI
validator, future bench PRs) can fail loudly on drift instead of
mis-parsing.

``RunLogger`` is also the single chokepoint for launcher status lines:
``print()`` goes to stdout unless ``--quiet``, while ``log_*`` always
lands in the JSONL file (when one is configured). Default behavior with
no flags is byte-identical to the old bare ``print`` calls.
"""
from __future__ import annotations

import dataclasses
import io
import json
import sys
import time
from typing import Any, Dict, Optional

RUNLOG_SCHEMA_VERSION = 1

# Keys every kind="step" record must carry — the CI schema gate
# (repro.obs.validate) and the report CLI both key off these.
STEP_REQUIRED_KEYS = (
    "schema", "kind", "step", "reward", "loss", "staleness_mean",
    "rollout_time_s", "train_time_s", "wall_time_s",
)


def step_record_dict(rec) -> Dict[str, Any]:
    """Flatten a ``StepRecord`` (or any dataclass/dict) into a JSON-ready
    step record, ``serving.*`` kept as a nested dict."""
    if dataclasses.is_dataclass(rec) and not isinstance(rec, type):
        d = dataclasses.asdict(rec)
    else:
        d = dict(rec)
    out: Dict[str, Any] = {"schema": RUNLOG_SCHEMA_VERSION, "kind": "step"}
    for k, v in d.items():
        if v is None:
            continue
        if isinstance(v, dict):
            out[k] = {kk: _scalar(vv) for kk, vv in v.items()}
        else:
            out[k] = _scalar(v)
    return out


def _scalar(v):
    if isinstance(v, (bool, int, str)) or v is None:
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class RunLogger:
    """Uniform run output: human stdout lines + optional JSONL sink.

    * ``print(msg)`` — human-facing status (suppressed by ``quiet``).
    * ``log_step(record)`` — one schema-versioned JSONL line per step.
    * ``log_event(kind, **fields)`` — run headers, checkpoints, etc.
    """

    def __init__(self, jsonl_path: Optional[str] = None,
                 quiet: bool = False,
                 stream: Optional[io.TextIOBase] = None):
        self.quiet = quiet
        self.jsonl_path = jsonl_path
        self.stream = stream if stream is not None else sys.stdout
        self._f = open(jsonl_path, "w") if jsonl_path else None
        self.steps_logged = 0
        self._t_open = time.time()

    # ------------------------------------------------------------- stdout
    def print(self, msg: str = "") -> None:
        if not self.quiet:
            print(msg, file=self.stream, flush=True)

    # -------------------------------------------------------------- jsonl
    def _write(self, record: Dict[str, Any]) -> Dict[str, Any]:
        if self._f is not None:
            json.dump(record, self._f)
            self._f.write("\n")
            self._f.flush()
        return record

    def log_step(self, rec) -> Dict[str, Any]:
        """Write one step record (a ``StepRecord``, dataclass, or dict)."""
        record = step_record_dict(rec)
        missing = [k for k in STEP_REQUIRED_KEYS if k not in record]
        assert not missing, f"step record missing required keys: {missing}"
        self.steps_logged += 1
        return self._write(record)

    def log_event(self, kind: str, **fields) -> Dict[str, Any]:
        record = {"schema": RUNLOG_SCHEMA_VERSION, "kind": kind,
                  "time_unix_s": time.time()}
        record.update({k: _scalar(v) if not isinstance(v, (dict, list))
                       else v for k, v in fields.items()})
        return self._write(record)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def read_jsonl(path: str, kind: Optional[str] = "step") -> list:
    """Load records from a run log (``kind=None`` keeps every record)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if kind is None or rec.get("kind") == kind:
                out.append(rec)
    return out
