"""Low-overhead span tracing with Chrome/Perfetto ``trace.json`` export.

Design constraints (the async loop is the hot path being measured):

* **Off by default, ~free when off.** ``span(...)`` checks one module
  global; when no tracer is installed it returns a shared no-op object —
  no allocation, no clock read. Instrumentation stays permanently in the
  library code.
* **Thread-aware.** Spans record the emitting thread; the rollout worker,
  the trainer loop, and benchmark threads land on separate Perfetto
  tracks (thread-name metadata events included), so the async
  interleaving A-3PO exploits is visually inspectable.
* **Monotonic clocks.** ``time.perf_counter_ns`` relative to tracer
  install; timestamps are microseconds as the trace-event format wants.
* **Causality.** ``flow_start``/``flow_end`` emit Chrome flow events
  (``ph: s/f``) that arrows a weight publish to the serving/rollout span
  that first ran under the published version.

Spans carry arbitrary key=value attributes (``args`` in the trace event),
e.g. per-span staleness, token counts, weight versions.

``annotate(name)`` additionally brackets a region with
``jax.profiler.TraceAnnotation`` so device profiles (``jax.profiler``)
line up with host spans — enabled together with the tracer, a no-op
otherwise.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

# ----------------------------------------------------------------- no-op path


class _NoopSpan:
    """Shared do-nothing span: the disabled-tracing fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:
        pass


_NOOP = _NoopSpan()


class _NoopAnnotation:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_ANNOTATION = _NoopAnnotation()


# ------------------------------------------------------------------- tracer
class _Span:
    """A live span; records a complete ("X") event on exit."""

    __slots__ = ("_tracer", "name", "_start_ns", "attrs")

    def __init__(self, tracer: "SpanTracer", name: str,
                 attrs: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._start_ns = 0

    def set(self, **attrs) -> None:
        """Attach attributes discovered mid-span (token counts etc.)."""
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)

    def __enter__(self):
        self._start_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        end = time.perf_counter_ns()
        self._tracer._complete(self.name, self._start_ns, end, self.attrs)
        return False


class SpanTracer:
    """Collects trace events; exports Chrome trace-event JSON.

    Thread safe: each event append takes one lock. Events are plain dicts
    in the Chrome trace 'X'/'s'/'f'/'C'/'M' phases; ``export`` writes the
    JSON-object-with-``traceEvents`` flavor Perfetto and chrome://tracing
    both load.
    """

    def __init__(self, process_name: str = "repro-a3po"):
        self._t0_ns = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._tids: Dict[int, int] = {}
        self._flow_started: set = set()
        self.process_name = process_name
        self._events.append({
            "ph": "M", "pid": 1, "tid": 0, "name": "process_name",
            "args": {"name": process_name}})

    # ------------------------------------------------------------- internals
    def _us(self, t_ns: int) -> float:
        return (t_ns - self._t0_ns) / 1e3

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[ident] = tid
            self._events.append({
                "ph": "M", "pid": 1, "tid": tid, "name": "thread_name",
                "args": {"name": threading.current_thread().name}})
        return tid

    def _complete(self, name: str, start_ns: int, end_ns: int,
                  attrs: Optional[Dict[str, Any]]) -> None:
        ev = {"ph": "X", "pid": 1, "name": name,
              "ts": self._us(start_ns),
              "dur": max((end_ns - start_ns) / 1e3, 0.001)}
        if attrs:
            ev["args"] = {k: _jsonable(v) for k, v in attrs.items()}
        with self._lock:
            ev["tid"] = self._tid()
            self._events.append(ev)

    # ------------------------------------------------------------------- api
    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs or None)

    def instant(self, name: str, **attrs) -> None:
        """A zero-duration marker ('i' phase)."""
        ev = {"ph": "i", "pid": 1, "name": name, "s": "t",
              "ts": self._us(time.perf_counter_ns())}
        if attrs:
            ev["args"] = {k: _jsonable(v) for k, v in attrs.items()}
        with self._lock:
            ev["tid"] = self._tid()
            self._events.append(ev)

    def counter(self, name: str, **values) -> None:
        """A counter-track sample ('C' phase) — e.g. queue depth."""
        ev = {"ph": "C", "pid": 1, "name": name,
              "ts": self._us(time.perf_counter_ns()),
              "args": {k: float(v) for k, v in values.items()}}
        with self._lock:
            ev["tid"] = self._tid()
            self._events.append(ev)

    def flow_start(self, name: str, flow_id: int, **attrs) -> None:
        """Open a flow arrow (must be emitted inside an open span)."""
        ev = {"ph": "s", "pid": 1, "name": name, "cat": "flow",
              "id": int(flow_id),
              "ts": self._us(time.perf_counter_ns())}
        if attrs:
            ev["args"] = {k: _jsonable(v) for k, v in attrs.items()}
        with self._lock:
            self._flow_started.add(int(flow_id))
            ev["tid"] = self._tid()
            self._events.append(ev)

    def flow_end(self, name: str, flow_id: int, **attrs) -> None:
        """Close a flow arrow; dropped if no matching ``flow_start``
        happened (e.g. resuming under the initial weights)."""
        with self._lock:
            if int(flow_id) not in self._flow_started:
                return
            ev = {"ph": "f", "pid": 1, "name": name, "cat": "flow",
                  "id": int(flow_id), "bp": "e",
                  "ts": self._us(time.perf_counter_ns()),
                  "tid": self._tid()}
            if attrs:
                ev["args"] = {k: _jsonable(v) for k, v in attrs.items()}
            self._flow_started.discard(int(flow_id))
            self._events.append(ev)

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def to_dict(self) -> Dict[str, Any]:
        return {"traceEvents": self.events(),
                "displayTimeUnit": "ms",
                "metadata": {"process": self.process_name,
                             "clock": "perf_counter_ns"}}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    try:
        return float(v)  # numpy / jax scalars
    except (TypeError, ValueError):
        return str(v)


# ----------------------------------------------------------- module controls
_TRACER: Optional[SpanTracer] = None
_ANNOTATE = False


def install_tracer(tracer: Optional[SpanTracer] = None, *,
                   annotate_jax: bool = False) -> Optional[SpanTracer]:
    """Install (or, with ``None``, remove) the process-wide tracer.

    ``annotate_jax=True`` additionally brackets ``annotate(...)`` regions
    with ``jax.profiler.TraceAnnotation`` so a concurrently captured
    device profile carries the same region names.
    """
    global _TRACER, _ANNOTATE
    _TRACER = tracer
    _ANNOTATE = bool(annotate_jax) and tracer is not None
    return tracer


def get_tracer() -> Optional[SpanTracer]:
    return _TRACER


def span(name: str, **attrs):
    """Context manager timing a region under the installed tracer.

    With no tracer installed this is one global load + returning a shared
    no-op object — safe to leave in hot loops.
    """
    t = _TRACER
    if t is None:
        return _NOOP
    return t.span(name, **attrs)


def instant(name: str, **attrs) -> None:
    """Zero-duration marker under the installed tracer (no-op otherwise) —
    fault injections, worker restarts, checkpoint restores."""
    t = _TRACER
    if t is not None:
        t.instant(name, **attrs)


def flow_start(name: str, flow_id: int, **attrs) -> None:
    t = _TRACER
    if t is not None:
        t.flow_start(name, flow_id, **attrs)


def flow_end(name: str, flow_id: int, **attrs) -> None:
    t = _TRACER
    if t is not None:
        t.flow_end(name, flow_id, **attrs)


def annotate(name: str):
    """``jax.profiler.TraceAnnotation`` bracket, active only when the
    tracer was installed with ``annotate_jax=True`` (profiling on)."""
    if not _ANNOTATE:
        return _NOOP_ANNOTATION
    import jax
    return jax.profiler.TraceAnnotation(name)


def step_annotation(step: int):
    """``jax.profiler.StepTraceAnnotation`` for the outer training step —
    groups device activity per step in a captured profile."""
    if not _ANNOTATE:
        return _NOOP_ANNOTATION
    import jax
    return jax.profiler.StepTraceAnnotation("train", step_num=step)


def trace_span(name: Optional[str] = None, **attrs):
    """Decorator form of ``span`` (span name defaults to the function's
    qualified name)."""
    def deco(fn):
        import functools
        sp_name = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapped(*a, **kw):
            t = _TRACER
            if t is None:
                return fn(*a, **kw)
            with t.span(sp_name, **attrs):
                return fn(*a, **kw)
        return wrapped
    return deco


# ----------------------------------------------------- phase classification
# Canonical leaf spans per loop phase. Aggregations (the report CLI, the
# quick-bench breakdown) sum ONLY these names so nested wrappers (e.g. the
# orchestrator's outer "train_step" around the trainer's "train_update")
# are never double counted.
PHASE_SPANS: Dict[str, str] = {
    "rollout_generate": "rollout",
    "serve_generate": "rollout",
    "prefill": "prefill",
    "prefill_chunk": "prefill",
    "decode_step": "decode",
    "decode_horizon": "decode",
    "prox_forward": "train",
    "train_update": "train",
    "weight_publish": "publish",
}


def phase_breakdown(events: List[Dict[str, Any]]) -> Dict[str, Dict[str, float]]:
    """Aggregate trace events into per-phase totals.

    Returns ``{phase: {"total_s", "count", "mean_ms"}}`` over the
    canonical ``PHASE_SPANS`` names.
    """
    acc: Dict[str, List[float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        phase = PHASE_SPANS.get(ev.get("name", ""))
        if phase is None:
            continue
        acc.setdefault(phase, []).append(ev.get("dur", 0.0))
    out: Dict[str, Dict[str, float]] = {}
    for phase, durs in sorted(acc.items()):
        total_us = sum(durs)
        out[phase] = {"total_s": total_us / 1e6,
                      "count": float(len(durs)),
                      "mean_ms": total_us / 1e3 / max(len(durs), 1)}
    return out
