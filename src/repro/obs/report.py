"""Run-report CLI: summarize a run's JSONL log (+ optional trace.json).

    python -m repro.obs.report --jsonl run.jsonl [--trace trace.json] \
        [--json report.json]

Renders (text, optionally machine-readable JSON):

* step/reward/loss summary and wall-clock totals
* per-phase time breakdown (rollout / prefill / decode / train / publish)
  from the trace's canonical spans
* the staleness distribution (from the last step's ``serving.*`` snapshot
  when the control plane ran, else per-step ``staleness_mean``)
* training + decode tokens/sec
* the weight-publish timeline (span start times from the trace)

This is the artifact future bench PRs commit alongside raw JSON.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

from repro.obs.runlog import read_jsonl
from repro.obs.tracing import phase_breakdown


def _fmt_s(s: float) -> str:
    return f"{s * 1e3:.1f}ms" if s < 1.0 else f"{s:.2f}s"


def summarize(steps: List[Dict[str, Any]],
              trace: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Aggregate step records (+ trace events) into a report dict."""
    out: Dict[str, Any] = {"num_steps": len(steps)}
    if not steps:
        return out
    last = steps[-1]
    out["schema"] = last.get("schema")
    out["wall_time_s"] = last.get("wall_time_s", 0.0)
    out["final_reward"] = last.get("reward")
    out["final_loss"] = last.get("loss")
    n = len(steps)
    out["mean_reward"] = sum(s.get("reward", 0.0) for s in steps) / n
    out["mean_staleness"] = (
        sum(s.get("staleness_mean", 0.0) for s in steps) / n)
    train_t = sum(s.get("train_time_s", 0.0) for s in steps)
    rollout_t = sum(s.get("rollout_time_s", 0.0) for s in steps)
    prox_t = sum(s.get("prox_time_s", 0.0) for s in steps)
    out["train_time_s"] = train_t
    out["rollout_time_s"] = rollout_t
    out["prox_time_s"] = prox_t
    tokens = sum(s.get("train_tokens", 0.0) for s in steps)
    out["train_tokens"] = tokens
    out["train_tokens_per_s"] = tokens / train_t if train_t > 0 else 0.0
    out["host_syncs_per_step"] = (
        sum(s.get("host_syncs", 0.0) for s in steps) / n)

    # resilience counters are cumulative — the last step's snapshot is the
    # run total (faults injected, worker restarts, skipped updates, ...)
    res = last.get("resilience")
    if res:
        out["resilience"] = dict(res)

    serving = last.get("serving")
    if serving:
        out["serving"] = {
            "staleness": {k.split("staleness_", 1)[1]: v
                          for k, v in serving.items()
                          if k.startswith("staleness_")},
            "ttft_s": {k.split("ttft_s_", 1)[1]: v
                       for k, v in serving.items()
                       if k.startswith("ttft_s_")},
            "decode_tokens_per_s": serving.get("decode_tokens_per_s"),
            "prefill_chunks": serving.get("prefill_chunks"),
            "prefix_hit_rate": serving.get("prefix_hit_rate"),
            "interrupts": serving.get("interrupts"),
            "resumed_sequences": serving.get("resumed_sequences"),
        }

    if trace is not None:
        events = trace.get("traceEvents", [])
        out["phases"] = phase_breakdown(events)
        out["publish_timeline_s"] = [
            round(ev["ts"] / 1e6, 6) for ev in events
            if ev.get("ph") == "X" and ev.get("name") == "weight_publish"]
        out["trace_events"] = len(events)
    return out


def render_load(summary: Dict[str, Any]) -> str:
    """Per-class SLO table for a ``kind="load_summary"`` record (the
    loadgen harness's run summary)."""
    lines: List[str] = []
    lines.append(
        f"load harness — policy {summary.get('policy', '?')}: "
        f"{summary.get('requests', 0)} requests over "
        f"{summary.get('virtual_time_s', 0.0):.2f}s virtual "
        f"({summary.get('completed', 0)} done, "
        f"{summary.get('dropped', 0)} dropped, "
        f"{summary.get('publishes', 0)} publishes)")
    classes = summary.get("classes") or {}
    slo = summary.get("slo") or {}
    if classes:
        lines.append(
            f"  {'class':<12s} {'subm':>5s} {'done':>5s} {'shed':>5s} "
            f"{'ttft_p50':>9s} {'ttft_p99':>9s} {'e2e_p99':>9s} "
            f"{'slo%':>6s} {'goodput':>10s}")
        for name, row in classes.items():
            tgt = slo.get(name, {})
            lines.append(
                f"  {name:<12s} {row.get('submitted', 0):>5.0f} "
                f"{row.get('completed', 0):>5.0f} "
                f"{row.get('shed', 0):>5.0f} "
                f"{_fmt_s(row.get('ttft_p50_s') or 0.0):>9s} "
                f"{_fmt_s(row.get('ttft_p99_s') or 0.0):>9s} "
                f"{_fmt_s(row.get('e2e_p99_s') or 0.0):>9s} "
                f"{100 * (row.get('slo_attainment') or 0.0):>5.1f}% "
                f"{row.get('goodput_tok_s') or 0.0:>6.1f} tok/s"
                + (f"  (ttft slo {_fmt_s(tgt['ttft_slo_s'])})"
                   if "ttft_slo_s" in tgt else ""))
    srv = summary.get("serving") or {}
    if srv:
        lines.append(
            "  drops: "
            f"staleness {srv.get('drops_staleness_budget', 0):.0f}  "
            f"max_preempts {srv.get('drops_max_preempts', 0):.0f}  "
            f"slo_shed {srv.get('drops_slo_shed', 0):.0f}   "
            "preempts: "
            f"staleness {srv.get('preemptions_staleness', 0):.0f}  "
            f"slo {srv.get('preemptions_slo', 0):.0f}")
    return "\n".join(lines)


def render(report: Dict[str, Any]) -> str:
    """Human-readable report text."""
    lines: List[str] = []
    n = report.get("num_steps", 0)
    lines.append(f"run report — {n} steps, schema "
                 f"{report.get('schema', '?')}")
    if not n:
        return "\n".join(lines)
    lines.append(
        f"  wall {_fmt_s(report['wall_time_s'])}  "
        f"reward {report['mean_reward']:.3f} (final "
        f"{report['final_reward']:.3f})  loss {report['final_loss']:+.4f}")
    lines.append(
        f"  train {_fmt_s(report['train_time_s'])} "
        f"({report['train_tokens_per_s']:.0f} tok/s, "
        f"{report['host_syncs_per_step']:.1f} syncs/step)  "
        f"rollout {_fmt_s(report['rollout_time_s'])}  "
        f"prox {_fmt_s(report['prox_time_s'])}")
    lines.append(f"  staleness mean {report['mean_staleness']:.2f}")
    srv = report.get("serving")
    if srv:
        st = srv.get("staleness", {})
        if st:
            lines.append(
                "  staleness dist (serving): "
                + "  ".join(f"{k}={st[k]:.2f}" for k in
                            ("mean", "p50", "p99", "max") if k in st)
                + f"  n={st.get('count', 0):.0f}")
        tt = srv.get("ttft_s", {})
        if tt.get("count"):
            lines.append(
                "  ttft: "
                + "  ".join(f"{k}={_fmt_s(tt[k])}" for k in
                            ("mean", "p50", "p99", "max") if k in tt)
                + f"  n={tt['count']:.0f}")
        lines.append(
            f"  decode {srv.get('decode_tokens_per_s') or 0.0:.0f} tok/s  "
            f"prefix-hit {(srv.get('prefix_hit_rate') or 0.0) * 100:.0f}%  "
            f"prefill-chunks {srv.get('prefill_chunks') or 0:.0f}  "
            f"interrupts {srv.get('interrupts') or 0:.0f} "
            f"(resumed {srv.get('resumed_sequences') or 0:.0f} seqs)")
    res = report.get("resilience")
    if res:
        def _r(name: str) -> float:
            # labeled counters (resilience_faults_injected_total{kind=..})
            # fold into their base name for the one-line summary
            return sum(v for k, v in res.items()
                       if k == name or k.startswith(name + "{"))
        lines.append("  resilience:")
        lines.append(
            f"    faults injected "
            f"{_r('resilience_faults_injected_total'):.0f}  "
            f"worker crashes {_r('resilience_worker_crashes_total'):.0f} "
            f"(restarts {_r('resilience_worker_restarts_total'):.0f}, "
            f"permanent {_r('resilience_worker_failures_total'):.0f})")
        lines.append(
            f"    skipped updates "
            f"{_r('resilience_skipped_updates_total'):.0f}  "
            f"rollbacks {_r('resilience_rollbacks_total'):.0f}  "
            f"publish retries "
            f"{_r('resilience_publish_retries_total'):.0f}  "
            f"checkpoints {_r('resilience_checkpoint_saves_total'):.0f} "
            f"(restores {_r('resilience_checkpoint_restores_total'):.0f})")
    phases = report.get("phases")
    if phases:
        lines.append("  phase breakdown (trace):")
        total = sum(p["total_s"] for p in phases.values()) or 1.0
        for name in ("rollout", "prefill", "decode", "train", "publish"):
            p = phases.get(name)
            if p is None:
                continue
            lines.append(
                f"    {name:8s} {_fmt_s(p['total_s']):>9s}  "
                f"{100 * p['total_s'] / total:5.1f}%  "
                f"x{p['count']:.0f} (mean {p['mean_ms']:.2f}ms)")
    pubs = report.get("publish_timeline_s")
    if pubs:
        head = ", ".join(f"{t:.3f}" for t in pubs[:8])
        more = f" … +{len(pubs) - 8}" if len(pubs) > 8 else ""
        lines.append(f"  publishes at t(s): {head}{more}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a run's JSONL log (+ optional trace.json)")
    p.add_argument("--jsonl", required=True, help="run log (JSONL)")
    p.add_argument("--trace", default=None, help="Chrome trace.json")
    p.add_argument("--json", dest="json_out", default=None,
                   help="also write the report as JSON to this path")
    args = p.parse_args(argv)

    records = read_jsonl(args.jsonl, kind=None)
    steps = [r for r in records if r.get("kind") == "step"]
    loads = [r for r in records if r.get("kind") == "load_summary"]
    trace = None
    if args.trace:
        with open(args.trace) as f:
            trace = json.load(f)
    report = summarize(steps, trace)
    if steps or not loads:
        print(render(report))
    if loads:
        # loadgen runs: the per-class SLO table (latest summary wins)
        print(render_load(loads[-1]))
        report["load"] = loads[-1]
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"report JSON -> {args.json_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
