"""Process-wide metrics registry: Counter / Gauge / Histogram with labels.

Everything is host-side and allocation-free on the hot path (fixed bucket
arrays, float adds) — the same discipline as the original
``serving/metrics.py`` this module subsumes. ``ServingMetrics`` is now a
thin facade that registers its histograms and counter fields here, and the
training engine publishes loss/iw/clipfrac/host_syncs gauges, so one
``registry.snapshot()`` covers the whole loop and
``registry.prometheus_text()`` is a scrape-style exposition dump.

Histogram notes (vs the pre-obs serving implementation):

* ``quantile`` interpolates linearly *within* the winning bucket
  (prometheus ``histogram_quantile`` semantics) instead of returning the
  raw bucket upper bound; the overflow bucket interpolates up to the
  observed max.
* ``max`` is tracked from ``-inf`` so negative observations report their
  true maximum; the empty histogram still exposes ``0.0``.
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple


def _label_suffix(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> Dict[str, float]:
        return {self.name: float(self.value)}


class Gauge:
    """Point-in-time value; ``fn`` makes it a callback gauge evaluated at
    snapshot time (how the ServingMetrics facade exposes its plain-int
    dataclass fields without changing any call site)."""

    __slots__ = ("name", "help", "value", "fn")

    def __init__(self, name: str, help: str = "",
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self.value = 0.0
        self.fn = fn

    def set(self, v: float) -> None:
        self.value = float(v)

    def get(self) -> float:
        if self.fn is not None:
            return float(self.fn())
        return self.value

    def snapshot(self) -> Dict[str, float]:
        return {self.name: self.get()}


class Histogram:
    """Fixed-bucket histogram (prometheus-style bucket upper bounds).

    Buckets are ``(-inf, b0], (b0, b1], ..., (b_{n-1}, +inf)``; the
    overflow count rides in ``counts[-1]``.
    """

    __slots__ = ("name", "help", "bounds", "counts", "total", "sum", "_max")

    def __init__(self, bounds: Sequence[float], name: str = "",
                 help: str = ""):
        self.name = name
        self.help = help
        self.bounds = tuple(float(b) for b in bounds)
        assert list(self.bounds) == sorted(self.bounds), \
            "histogram bounds must be sorted"
        self.counts = [0] * (len(self.bounds) + 1)  # last = +inf overflow
        self.total = 0
        self.sum = 0.0
        self._max = -math.inf

    def observe(self, x: float) -> None:
        i = 0
        for b in self.bounds:
            if x <= b:
                break
            i += 1
        self.counts[i] += 1
        self.total += 1
        self.sum += x
        if x > self._max:
            self._max = x

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else 0.0

    @property
    def max(self) -> float:
        """True observed maximum (``0.0`` when empty)."""
        return self._max if self.total else 0.0

    def quantile(self, q: float) -> float:
        """Linearly interpolated quantile estimate (0 < q <= 1).

        Within the winning bucket the value is interpolated between the
        bucket's lower and upper bound (the first bucket's lower bound is
        ``min(0, bounds[0])``, prometheus-style); a quantile landing in
        the overflow bucket interpolates between ``bounds[-1]`` and the
        observed max.
        """
        if not self.total:
            return 0.0
        target = q * self.total
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= target and c > 0:
                frac = (target - seen) / c
                if i == 0:
                    lo = min(0.0, self.bounds[0]) if self.bounds else 0.0
                    hi = self.bounds[0] if self.bounds else self.max
                elif i < len(self.bounds):
                    lo, hi = self.bounds[i - 1], self.bounds[i]
                else:  # overflow: up to the true observed max
                    lo = self.bounds[-1] if self.bounds else 0.0
                    hi = max(self.max, lo)
                return lo + frac * (hi - lo)
            seen += c
        return self.max

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` (same bounds) into this histogram in place —
        multi-engine / multi-run aggregation."""
        assert self.bounds == other.bounds, \
            f"bucket mismatch: {self.bounds} vs {other.bounds}"
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.total += other.total
        self.sum += other.sum
        if other.total and other._max > self._max:
            self._max = other._max
        return self

    def snapshot(self, prefix: Optional[str] = None) -> Dict[str, float]:
        p = prefix if prefix is not None else self.name
        return {
            f"{p}_mean": self.mean,
            f"{p}_p50": self.quantile(0.5),
            f"{p}_p99": self.quantile(0.99),
            f"{p}_max": self.max,
            f"{p}_count": float(self.total),
        }


class MetricsRegistry:
    """Names -> metric objects; get-or-create constructors, labeled
    children, one flattened ``snapshot()``, prometheus text exposition."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    # ------------------------------------------------------------ factories
    def _get_or_create(self, name: str, factory, kind) -> object:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = factory()
                self._metrics[name] = m
            elif not isinstance(m, kind):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str, help: str = "",
                **labels) -> Counter:
        full = name + _label_suffix(labels)
        return self._get_or_create(full, lambda: Counter(full, help),
                                   Counter)

    def gauge(self, name: str, help: str = "",
              fn: Optional[Callable[[], float]] = None,
              **labels) -> Gauge:
        full = name + _label_suffix(labels)
        g = self._get_or_create(full, lambda: Gauge(full, help, fn), Gauge)
        if fn is not None:
            g.fn = fn  # re-registration rebinds the callback (new facade)
        return g

    def histogram(self, name: str, bounds: Sequence[float],
                  help: str = "", **labels) -> Histogram:
        full = name + _label_suffix(labels)
        return self._get_or_create(
            full, lambda: Histogram(bounds, full, help), Histogram)

    def register(self, name: str, metric: object,
                 replace: bool = True) -> object:
        """Adopt an externally constructed metric (the ServingMetrics
        facade re-registers its histograms on each instantiation)."""
        with self._lock:
            if not replace and name in self._metrics:
                raise ValueError(f"metric {name!r} already registered")
            self._metrics[name] = metric
            return metric

    def unregister_prefix(self, prefix: str) -> None:
        with self._lock:
            for k in [k for k in self._metrics if k.startswith(prefix)]:
                del self._metrics[k]

    # ------------------------------------------------------------ snapshots
    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, float] = {}
        for name, m in items:
            if isinstance(m, Histogram):
                out.update(m.snapshot(name))
            else:
                out.update(m.snapshot())  # type: ignore[union-attr]
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (0.0.4) dump."""
        with self._lock:
            items = list(self._metrics.items())
        lines: List[str] = []

        def base_and_labels(full: str) -> Tuple[str, str]:
            if "{" in full:
                i = full.index("{")
                return full[:i], full[i:]
            return full, ""

        for name, m in items:
            base, labels = base_and_labels(name)
            if isinstance(m, Counter):
                if m.help:
                    lines.append(f"# HELP {base} {m.help}")
                lines.append(f"# TYPE {base} counter")
                lines.append(f"{name} {m.value:g}")
            elif isinstance(m, Gauge):
                if m.help:
                    lines.append(f"# HELP {base} {m.help}")
                lines.append(f"# TYPE {base} gauge")
                lines.append(f"{name} {m.get():g}")
            elif isinstance(m, Histogram):
                if m.help:
                    lines.append(f"# HELP {base} {m.help}")
                lines.append(f"# TYPE {base} histogram")
                inner = labels[1:-1] if labels else ""
                cum = 0
                for b, c in zip(m.bounds, m.counts):
                    cum += c
                    lab = (inner + "," if inner else "") + f'le="{b:g}"'
                    lines.append(f"{base}_bucket{{{lab}}} {cum}")
                lab = (inner + "," if inner else "") + 'le="+Inf"'
                lines.append(f"{base}_bucket{{{lab}}} {m.total}")
                lines.append(f"{base}_sum{labels} {m.sum:g}")
                lines.append(f"{base}_count{labels} {m.total}")
        return "\n".join(lines) + "\n"

    def dump_prometheus(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.prometheus_text())
        return path


# ------------------------------------------------------------ global registry
_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide registry (orchestrator, serving facade, trainer,
    and benchmarks all publish here)."""
    return _REGISTRY
