"""CI schema gate: validate a run's JSONL log and trace.json.

    python -m repro.obs.validate --jsonl run.jsonl [--trace trace.json] \
        [--min-steps N] [--expect-span NAME ...]
    python -m repro.obs.validate --jsonl load_run.jsonl --loadgen \
        [--min-requests N]

Fails (exit 1) when:
* any JSONL step record is missing a required key or carries a schema
  version other than ``RUNLOG_SCHEMA_VERSION`` (schema drift);
* fewer than ``--min-steps`` step records were emitted;
* the trace is not valid Chrome trace-event JSON (``traceEvents`` list of
  events with ``ph``/``ts``), or an ``--expect-span`` name is absent;
* with ``--loadgen``: a request-lifecycle record is missing a required
  key, no ``load_summary`` record closes the run, or fewer than
  ``--min-requests`` lifecycle records were emitted.
"""
from __future__ import annotations

import argparse
import json
from typing import List, Optional

from repro.obs.runlog import (
    RUNLOG_SCHEMA_VERSION,
    STEP_REQUIRED_KEYS,
    read_jsonl,
)


def validate_jsonl(path: str, min_steps: int = 1) -> List[str]:
    errors: List[str] = []
    try:
        steps = read_jsonl(path, kind="step")
    except (OSError, json.JSONDecodeError) as e:
        return [f"jsonl unreadable: {e!r}"]
    if len(steps) < min_steps:
        errors.append(f"expected >= {min_steps} step records, "
                      f"got {len(steps)}")
    for i, rec in enumerate(steps):
        if rec.get("schema") != RUNLOG_SCHEMA_VERSION:
            errors.append(f"record {i}: schema {rec.get('schema')!r} != "
                          f"{RUNLOG_SCHEMA_VERSION}")
        missing = [k for k in STEP_REQUIRED_KEYS if k not in rec]
        if missing:
            errors.append(f"record {i}: missing keys {missing}")
    return errors


def validate_loadgen_jsonl(path: str, min_requests: int = 1) -> List[str]:
    """Schema-gate the load harness's lifecycle JSONL."""
    from repro.loadgen.traces import (
        LIFECYCLE_REQUIRED_KEYS,
        SUMMARY_REQUIRED_KEYS,
    )
    errors: List[str] = []
    try:
        records = read_jsonl(path, kind=None)
    except (OSError, json.JSONDecodeError) as e:
        return [f"jsonl unreadable: {e!r}"]
    reqs = [r for r in records if r.get("kind") == "request"]
    summaries = [r for r in records if r.get("kind") == "load_summary"]
    if len(reqs) < min_requests:
        errors.append(f"expected >= {min_requests} request records, "
                      f"got {len(reqs)}")
    for i, rec in enumerate(reqs):
        if rec.get("schema") != RUNLOG_SCHEMA_VERSION:
            errors.append(f"request {i}: schema {rec.get('schema')!r} != "
                          f"{RUNLOG_SCHEMA_VERSION}")
        missing = [k for k in LIFECYCLE_REQUIRED_KEYS if k not in rec]
        if missing:
            errors.append(f"request {i}: missing keys {missing}")
        if rec.get("outcome") not in ("done", "dropped"):
            errors.append(f"request {i}: bad outcome "
                          f"{rec.get('outcome')!r}")
    if not summaries:
        errors.append("no load_summary record")
    for rec in summaries:
        missing = [k for k in SUMMARY_REQUIRED_KEYS if k not in rec]
        if missing:
            errors.append(f"load_summary: missing keys {missing}")
    return errors


def validate_trace(path: str,
                   expect_spans: Optional[List[str]] = None) -> List[str]:
    errors: List[str] = []
    try:
        with open(path) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"trace unreadable: {e!r}"]
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["trace has no traceEvents list"]
    for i, ev in enumerate(events):
        if "ph" not in ev or "pid" not in ev:
            errors.append(f"event {i}: missing ph/pid")
            break
        if ev["ph"] != "M" and "ts" not in ev:
            errors.append(f"event {i} ({ev.get('name')}): missing ts")
            break
    names = {ev.get("name") for ev in events if ev.get("ph") == "X"}
    for want in expect_spans or []:
        if want not in names:
            errors.append(f"expected span {want!r} absent "
                          f"(have: {sorted(n for n in names if n)})")
    return errors


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.obs.validate")
    p.add_argument("--jsonl", default=None)
    p.add_argument("--trace", default=None)
    p.add_argument("--min-steps", type=int, default=1)
    p.add_argument("--expect-span", action="append", default=[],
                   help="span name that must appear in the trace "
                        "(repeatable)")
    p.add_argument("--loadgen", action="store_true",
                   help="validate load-harness lifecycle JSONL instead "
                        "of step records")
    p.add_argument("--min-requests", type=int, default=1)
    args = p.parse_args(argv)
    assert args.jsonl or args.trace, "nothing to validate"

    errors: List[str] = []
    if args.jsonl and args.loadgen:
        errors += [f"[loadgen] {e}"
                   for e in validate_loadgen_jsonl(args.jsonl,
                                                   args.min_requests)]
    elif args.jsonl:
        errors += [f"[jsonl] {e}"
                   for e in validate_jsonl(args.jsonl, args.min_steps)]
    if args.trace:
        errors += [f"[trace] {e}"
                   for e in validate_trace(args.trace, args.expect_span)]
    if errors:
        for e in errors:
            print(f"VALIDATION FAILED: {e}")
        return 1
    print("obs validation OK"
          + (f" — jsonl {args.jsonl}" if args.jsonl else "")
          + (f" — trace {args.trace}" if args.trace else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
