"""Advantage estimation: group reward normalization (GRPO-style, §4.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def group_normalized_advantages(rewards: jax.Array, group_size: int,
                                eps: float = 1e-6) -> jax.Array:
    """rewards [B] with B = n_prompts * group_size (grouped contiguously).

    A_i = (r_i - mean_group) / (std_group + eps); broadcast per-token by the
    caller. This is the paper's 'group reward normalization'.
    """
    B = rewards.shape[0]
    assert B % group_size == 0, (B, group_size)
    g = rewards.reshape(B // group_size, group_size).astype(jnp.float32)
    mean = g.mean(axis=1, keepdims=True)
    std = g.std(axis=1, keepdims=True)
    adv = (g - mean) / (std + eps)
    return adv.reshape(B)


def broadcast_over_tokens(adv: jax.Array, mask: jax.Array) -> jax.Array:
    """[B] sequence advantages -> [B, T] token advantages (masked)."""
    return adv[:, None] * mask.astype(jnp.float32)
