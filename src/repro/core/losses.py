"""Thin compatibility layer over ``core.objective`` / ``core.algorithms``.

The policy-gradient objectives (coupled PPO/GRPO, decoupled PPO, fused
A-3PO, and the registry-pluggable algorithms) live in
``repro.core.objective`` and ``repro.core.algorithms``. This module keeps
the original import surface (``policy_loss`` and the two modular losses)
stable for older call sites and tests; stringly-typed ``method`` dispatch
through it resolves via the Algorithm registry and emits a
``DeprecationWarning``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

from repro.configs.base import RLConfig
from repro.core.algorithms import (  # noqa: F401
    Algorithm,
    LossInputs,
    get_algorithm,
    resolve_algorithm,
)
from repro.core.objective import (  # noqa: F401
    Metrics,
    coupled_ppo_loss,
    decoupled_ppo_loss,
    policy_objective,
)


def policy_loss(
    method,
    logp: jax.Array,
    behav_logp: jax.Array,
    advantages: jax.Array,
    mask: jax.Array,
    cfg: RLConfig,
    *,
    versions: Optional[jax.Array] = None,
    current_version=None,
    recomputed_prox_logp: Optional[jax.Array] = None,
    entropy: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Legacy dispatch: ``method`` may be an ``Algorithm`` or a registry
    name ('sync' / 'recompute' / 'a3po' aka 'loglinear' / ...). Delegates
    to ``objective.policy_objective`` (names warn, then resolve)."""
    return policy_objective(
        method, logp, behav_logp, advantages, mask, cfg,
        versions=versions, current_version=current_version,
        recomputed_prox_logp=recomputed_prox_logp, entropy=entropy)
