"""Policy-gradient objectives: coupled PPO/GRPO, decoupled PPO, A-3PO.

All losses operate on per-token log-probabilities (what the rollout engine
and the model's scoring path produce) and a per-token response mask. They
return (scalar_loss, metrics) where metrics mirror the paper's Figs. 4-6:
entropy, clipped-token counts, importance-weight max/min.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RLConfig
from repro.core.a3po import (
    compute_prox_logp_approximation,
    compute_prox_logp_kl_adaptive,
)

Metrics = Dict[str, jax.Array]


def _masked_mean(x: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _masked_max(x, mask):
    return jnp.max(jnp.where(mask > 0, x, -jnp.inf))


def _masked_min(x, mask):
    return jnp.min(jnp.where(mask > 0, x, jnp.inf))


def _clip_objective(ratio: jax.Array, adv: jax.Array, eps: float
                    ) -> Tuple[jax.Array, jax.Array]:
    """PPO clipped surrogate per token. Returns (objective, clipped_mask)."""
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - eps, 1.0 + eps) * adv
    obj = jnp.minimum(unclipped, clipped)
    was_clipped = (unclipped > clipped).astype(jnp.float32)
    return obj, was_clipped


def coupled_ppo_loss(
    logp: jax.Array,        # log pi_theta  [B, T]
    behav_logp: jax.Array,  # log pi_behav  [B, T]
    advantages: jax.Array,  # [B, T] (already broadcast / normalized)
    mask: jax.Array,        # [B, T] response mask
    cfg: RLConfig,
    entropy: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Metrics]:
    """Standard PPO/GRPO (Eq. 1): pi_old doubles as IS weight + anchor."""
    logp = logp.astype(jnp.float32)
    behav_logp = behav_logp.astype(jnp.float32)
    ratio = jnp.exp(logp - behav_logp)
    obj, was_clipped = _clip_objective(ratio, advantages, cfg.clip_eps)
    loss = -_masked_mean(obj, mask)
    metrics = _common_metrics(ratio, ratio, was_clipped, mask, entropy)
    if entropy is not None and cfg.entropy_coef:
        loss = loss - cfg.entropy_coef * _masked_mean(entropy, mask)
    return loss, metrics


def decoupled_ppo_loss(
    logp: jax.Array,
    behav_logp: jax.Array,
    prox_logp: jax.Array,   # frozen trust-region anchor [B, T]
    advantages: jax.Array,
    mask: jax.Array,
    cfg: RLConfig,
    entropy: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Metrics]:
    """Decoupled loss (Eq. 2): behavior IS weight x prox-anchored clip."""
    logp = logp.astype(jnp.float32)
    behav_logp = behav_logp.astype(jnp.float32)
    prox_logp = jax.lax.stop_gradient(prox_logp.astype(jnp.float32))
    # importance weight pi_prox / pi_behav — detached, capped for stability
    iw = jnp.exp(prox_logp - behav_logp)
    iw = jnp.minimum(iw, cfg.behav_weight_cap)
    iw = jax.lax.stop_gradient(iw)
    # trust-region ratio pi_theta / pi_prox
    ratio = jnp.exp(logp - prox_logp)
    obj, was_clipped = _clip_objective(ratio, advantages, cfg.clip_eps)
    loss = -_masked_mean(iw * obj, mask)
    metrics = _common_metrics(iw, ratio, was_clipped, mask, entropy)
    if entropy is not None and cfg.entropy_coef:
        loss = loss - cfg.entropy_coef * _masked_mean(entropy, mask)
    return loss, metrics


def policy_loss(
    method: str,
    logp: jax.Array,
    behav_logp: jax.Array,
    advantages: jax.Array,
    mask: jax.Array,
    cfg: RLConfig,
    *,
    versions: Optional[jax.Array] = None,
    current_version=None,
    recomputed_prox_logp: Optional[jax.Array] = None,
    entropy: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Metrics]:
    """Dispatch: 'sync' (coupled), 'recompute' (decoupled, explicit prox),
    'loglinear' (A-3PO)."""
    if method == "sync":
        return coupled_ppo_loss(logp, behav_logp, advantages, mask, cfg,
                                entropy)
    if method == "recompute":
        assert recomputed_prox_logp is not None, \
            "recompute method needs the explicit prox forward pass"
        return decoupled_ppo_loss(logp, behav_logp, recomputed_prox_logp,
                                  advantages, mask, cfg, entropy)
    if method == "loglinear":
        if cfg.alpha_schedule == "kl_adaptive":  # beyond-paper controller
            prox = compute_prox_logp_kl_adaptive(behav_logp, logp, mask)
        else:
            assert versions is not None and current_version is not None
            prox = compute_prox_logp_approximation(
                behav_logp, logp, versions, current_version, cfg)
        return decoupled_ppo_loss(logp, behav_logp, prox, advantages, mask,
                                  cfg, entropy)
    raise ValueError(f"unknown method {method!r}")


def _common_metrics(iw, ratio, was_clipped, mask, entropy) -> Metrics:
    m: Metrics = {
        "iw_max": _masked_max(iw, mask),
        "iw_min": _masked_min(iw, mask),
        "iw_mean": _masked_mean(iw, mask),
        "ratio_mean": _masked_mean(ratio, mask),
        "clipped_tokens": jnp.sum(was_clipped * mask),
        "clipped_frac": _masked_mean(was_clipped, mask),
    }
    if entropy is not None:
        m["entropy"] = _masked_mean(entropy, mask)
    return m
