"""Thin compatibility layer over ``core.objective``.

The policy-gradient objectives (coupled PPO/GRPO, decoupled PPO, fused
A-3PO) live in ``repro.core.objective`` — the unified, kernel-backed
interface the training engine scans over. This module keeps the original
import surface (``policy_loss`` and the two modular losses) stable for
older call sites and tests.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

from repro.configs.base import RLConfig
from repro.core.objective import (  # noqa: F401
    Metrics,
    coupled_ppo_loss,
    decoupled_ppo_loss,
    policy_objective,
)


def policy_loss(
    method: str,
    logp: jax.Array,
    behav_logp: jax.Array,
    advantages: jax.Array,
    mask: jax.Array,
    cfg: RLConfig,
    *,
    versions: Optional[jax.Array] = None,
    current_version=None,
    recomputed_prox_logp: Optional[jax.Array] = None,
    entropy: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Dispatch: 'sync' (coupled), 'recompute' (decoupled, explicit prox),
    'loglinear' (A-3PO, fused kernel). Delegates to
    ``objective.policy_objective``."""
    return policy_objective(
        method, logp, behav_logp, advantages, mask, cfg,
        versions=versions, current_version=current_version,
        recomputed_prox_logp=recomputed_prox_logp, entropy=entropy)
