"""Unified A-3PO training objective (the hot inner loop of the engine).

One interface for the three methods the paper compares:

* ``sync``      — coupled PPO/GRPO (Eq. 1): pi_old is IS weight + anchor.
* ``recompute`` — decoupled PPO (Eq. 2) with an explicitly recomputed
                  proximal anchor (the forward pass A-3PO deletes).
* ``loglinear`` — A-3PO (Eq. 3-4 / Listing 1): the anchor is a log-linear
                  interpolation weighted by the staleness-aware alpha.

``resolve_alpha`` is the single dispatch point for every alpha schedule —
including the beyond-paper ``kl_adaptive`` controller, which needs the
live/behavior logps and therefore cannot be computed from staleness alone.

The ``loglinear`` clipped-surrogate inner loop routes through the fused
``kernels/a3po_loss`` Pallas kernel (interpret mode off-TPU) behind a
``custom_vjp``: one fused elementwise pass computes loss, clip indicators,
importance weights, and trust-region ratios; the backward pass is the
analytic gradient, with the pure-jnp ref as the oracle. Alpha is computed
from the ``[B]`` or ``[B, T]`` version stamps and broadcast into the fused
path. ``core.losses`` is a thin compatibility layer over this module.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RLConfig
from repro.core.a3po import (
    alpha_from_staleness,
    kl_adaptive_alpha,
    staleness,
)
from repro.kernels.a3po_loss import a3po_objective

Metrics = Dict[str, jax.Array]


def _masked_mean(x: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _masked_max(x, mask):
    return jnp.max(jnp.where(mask > 0, x, -jnp.inf))


def _masked_min(x, mask):
    return jnp.min(jnp.where(mask > 0, x, jnp.inf))


def _clip_objective(ratio: jax.Array, adv: jax.Array, eps: float
                    ) -> Tuple[jax.Array, jax.Array]:
    """PPO clipped surrogate per token. Returns (objective, clipped_mask)."""
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - eps, 1.0 + eps) * adv
    obj = jnp.minimum(unclipped, clipped)
    was_clipped = (unclipped > clipped).astype(jnp.float32)
    return obj, was_clipped


# public names for Algorithm plugins (core.algorithms and third parties)
masked_mean = _masked_mean
clip_objective = _clip_objective


def _common_metrics(iw, ratio, was_clipped, mask, entropy) -> Metrics:
    m: Metrics = {
        "iw_max": _masked_max(iw, mask),
        "iw_min": _masked_min(iw, mask),
        "iw_mean": _masked_mean(iw, mask),
        "ratio_mean": _masked_mean(ratio, mask),
        "clipped_tokens": jnp.sum(was_clipped * mask),
        "clipped_frac": _masked_mean(was_clipped, mask),
    }
    if entropy is not None:
        m["entropy"] = _masked_mean(entropy, mask)
    return m


common_metrics = _common_metrics


def apply_regularizers(loss: jax.Array, metrics: Metrics, logp: jax.Array,
                       anchor_logp: jax.Array, mask: jax.Array,
                       cfg: RLConfig, entropy: Optional[jax.Array]
                       ) -> Tuple[jax.Array, Metrics]:
    """Shared loss tail for every algorithm: KL penalty + entropy bonus.

    ``kl`` is the k1 estimator of KL(pi_theta || anchor) on the response
    tokens — the anchor is whatever trust-region reference the algorithm
    uses (behavior, recomputed prox, log-linear prox). It is always
    reported in ``metrics`` and added to the loss when ``cfg.kl_coef`` is
    set (this is the wiring of the previously-dead ``RLConfig.kl_coef``).
    """
    kl = _masked_mean(
        logp.astype(jnp.float32)
        - jax.lax.stop_gradient(anchor_logp.astype(jnp.float32)), mask)
    metrics["kl"] = kl
    if cfg.kl_coef:
        loss = loss + cfg.kl_coef * kl
    if entropy is not None and cfg.entropy_coef:
        loss = loss - cfg.entropy_coef * metrics["entropy"]
    return loss, metrics


# ------------------------------------------------------------- alpha dispatch
def resolve_alpha(
    cfg: RLConfig,
    *,
    versions: Optional[jax.Array] = None,
    current_version=None,
    logp: Optional[jax.Array] = None,
    behav_logp: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    schedule: Optional[str] = None,
) -> jax.Array:
    """The one place every alpha schedule is dispatched from.

    Staleness schedules (inverse/exp/clipped/const) need the ``[B]`` or
    ``[B, T]`` version stamps; ``kl_adaptive`` needs the live/behavior
    logps and yields a per-sequence ``[B, 1]``. The result broadcasts
    against ``[B, T]`` token tensors in all cases and carries no gradient.
    """
    schedule = schedule or cfg.alpha_schedule
    if schedule == "kl_adaptive":
        assert logp is not None and behav_logp is not None \
            and mask is not None, "kl_adaptive alpha needs logps + mask"
        return kl_adaptive_alpha(behav_logp, logp, mask)
    assert versions is not None and current_version is not None, \
        f"schedule {schedule!r} needs version stamps"
    return alpha_from_staleness(staleness(versions, current_version), cfg,
                                schedule)


# ------------------------------------------------------------------ jnp paths
def coupled_ppo_loss(
    logp: jax.Array,        # log pi_theta  [B, T]
    behav_logp: jax.Array,  # log pi_behav  [B, T]
    advantages: jax.Array,  # [B, T] (already broadcast / normalized)
    mask: jax.Array,        # [B, T] response mask
    cfg: RLConfig,
    entropy: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Metrics]:
    """Standard PPO/GRPO (Eq. 1): pi_old doubles as IS weight + anchor."""
    logp = logp.astype(jnp.float32)
    behav_logp = behav_logp.astype(jnp.float32)
    ratio = jnp.exp(logp - behav_logp)
    obj, was_clipped = _clip_objective(ratio, advantages, cfg.clip_eps)
    loss = -_masked_mean(obj, mask)
    metrics = _common_metrics(ratio, ratio, was_clipped, mask, entropy)
    return apply_regularizers(loss, metrics, logp, behav_logp, mask, cfg,
                              entropy)


def decoupled_ppo_loss(
    logp: jax.Array,
    behav_logp: jax.Array,
    prox_logp: jax.Array,   # frozen trust-region anchor [B, T]
    advantages: jax.Array,
    mask: jax.Array,
    cfg: RLConfig,
    entropy: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Metrics]:
    """Decoupled loss (Eq. 2): behavior IS weight x prox-anchored clip."""
    logp = logp.astype(jnp.float32)
    behav_logp = behav_logp.astype(jnp.float32)
    prox_logp = jax.lax.stop_gradient(prox_logp.astype(jnp.float32))
    # importance weight pi_prox / pi_behav — detached, capped for stability
    iw = jnp.exp(prox_logp - behav_logp)
    iw = jnp.minimum(iw, cfg.behav_weight_cap)
    iw = jax.lax.stop_gradient(iw)
    # trust-region ratio pi_theta / pi_prox
    ratio = jnp.exp(logp - prox_logp)
    obj, was_clipped = _clip_objective(ratio, advantages, cfg.clip_eps)
    loss = -_masked_mean(iw * obj, mask)
    metrics = _common_metrics(iw, ratio, was_clipped, mask, entropy)
    return apply_regularizers(loss, metrics, logp, prox_logp, mask, cfg,
                              entropy)


# ----------------------------------------------------------------- fused path
def fused_a3po_loss(
    logp: jax.Array,
    behav_logp: jax.Array,
    alpha: jax.Array,       # [B, T], [B, 1] or [B] — broadcast over tokens
    advantages: jax.Array,
    mask: jax.Array,
    cfg: RLConfig,
    entropy: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Metrics]:
    """A-3PO decoupled loss through the fused kernel + analytic VJP.

    Numerically identical to ``decoupled_ppo_loss`` over the log-linear
    anchor ``alpha * behav + (1 - alpha) * logp`` — but prox interpolation,
    IS weight, ratio, clip, and masking run as one fused pass, and the
    iw/ratio metric tensors fall out of the same pass.
    """
    logp = logp.astype(jnp.float32)
    behav_logp = behav_logp.astype(jnp.float32)
    if alpha.ndim == logp.ndim - 1:
        alpha = alpha[..., None]
    alpha = jax.lax.stop_gradient(
        jnp.broadcast_to(alpha, logp.shape).astype(jnp.float32))
    loss_tok, clip_tok, iw, ratio = a3po_objective(
        logp, behav_logp, alpha, advantages, mask,
        clip_eps=cfg.clip_eps, iw_cap=cfg.behav_weight_cap)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(loss_tok) / denom
    metrics: Metrics = {
        "iw_max": _masked_max(iw, mask),
        "iw_min": _masked_min(iw, mask),
        "iw_mean": _masked_mean(iw, mask),
        "ratio_mean": _masked_mean(ratio, mask),
        "clipped_tokens": jnp.sum(clip_tok),
        "clipped_frac": jnp.sum(clip_tok) / denom,
    }
    if entropy is not None:
        metrics["entropy"] = _masked_mean(entropy, mask)
    # the log-linear anchor, reconstructed for the shared KL path (the
    # fused kernel keeps it internal)
    anchor = alpha * behav_logp + (1.0 - alpha) * logp
    return apply_regularizers(loss, metrics, logp, anchor, mask, cfg,
                              entropy)


# ------------------------------------------------------------------- dispatch
def policy_objective(
    algo=None,
    logp: Optional[jax.Array] = None,
    behav_logp: Optional[jax.Array] = None,
    advantages: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    cfg: Optional[RLConfig] = None,
    *,
    versions: Optional[jax.Array] = None,
    current_version=None,
    recomputed_prox_logp: Optional[jax.Array] = None,
    entropy: Optional[jax.Array] = None,
    method: Optional[str] = None,
) -> Tuple[jax.Array, Metrics]:
    """Unified objective, dispatched through the Algorithm registry.

    ``algo`` is an ``Algorithm`` instance (``repro.core.algorithms``) or a
    registry name. Stringly-typed dispatch — a name positionally or the
    legacy ``method=`` keyword — still resolves through the registry but
    emits a ``DeprecationWarning``; new call sites should pass
    ``get_algorithm("a3po")`` (or any registered Algorithm) directly.
    """
    import warnings

    from repro.core.algorithms import Algorithm, LossInputs, get_algorithm

    if method is not None:
        warnings.warn(
            "policy_objective(method=...) is deprecated; pass an Algorithm "
            "from repro.core.algorithms (e.g. get_algorithm('a3po'))",
            DeprecationWarning, stacklevel=2)
        if algo is None:
            algo = method
    if isinstance(algo, str):
        if method is None:
            warnings.warn(
                f"stringly-typed policy_objective({algo!r}, ...) is "
                "deprecated; pass an Algorithm from repro.core.algorithms",
                DeprecationWarning, stacklevel=2)
        algo = get_algorithm(algo)
    assert isinstance(algo, Algorithm), algo
    batch = LossInputs(
        behav_logp=behav_logp, advantages=advantages, mask=mask,
        versions=versions, current_version=current_version,
        prox_logp=recomputed_prox_logp, entropy=entropy)
    return algo.loss(logp, batch, cfg or RLConfig())
