"""First-class Algorithm API: pluggable policy-optimization algorithms.

The paper frames A-3PO as one point in a *family* of asynchronous
policy-optimization objectives. This module makes that family a registry
instead of ``method: str`` branches scattered across five layers: an
``Algorithm`` is a frozen, hashable dataclass (so it rides into jit static
args next to ``ModelConfig``/``RLConfig``) that declares

* its **data requirements** as class-level flags — ``needs_behav_logp``,
  ``needs_prox_forward``, ``needs_versions``, ``needs_group_rewards`` —
  which the training engine reads to decide what it computes and threads
  through the compiled minibatch scan at all (e.g. only ``recompute`` pays
  the extra prox forward pass);
* its **loss**: ``loss(logp, batch, cfg) -> (loss, Metrics)`` over a
  ``LossInputs`` bundle; every loss must emit the full shared metric set
  (`_common_metrics` + ``kl``) so the engine's packed one-transfer metrics
  vector stays algorithm-independent;
* optional **hooks**: ``advantages`` (defaults to GRPO group
  normalization) and ``alpha`` (defaults to ``resolve_alpha``'s unified
  schedule dispatch).

Built-ins: the paper's three methods (``sync``, ``recompute``, ``a3po``
with alias ``loglinear`` — still routed through the fused Pallas
``kernels/a3po_loss`` path) plus two beyond-paper algorithms the API makes
one-file plugins: ``asympo`` (behavior-free asymmetric-scale correction,
after ASymPO) and ``grpo_mu`` (staleness-gated importance-weight
truncation, after mu-GRPO).

Registering a new algorithm:

    @register("my_algo")
    @dataclasses.dataclass(frozen=True)
    class MyAlgo(Algorithm):
        my_knob: float = 1.0
        def loss(self, logp, batch, cfg):
            ...
            return loss, metrics

``Trainer(cfg, rl, "my_algo")`` / ``launch/train.py --algo my_algo`` then
work end-to-end with no other edits.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, ClassVar, Dict, List, NamedTuple,
                    Optional, Tuple, Type)

import jax
import jax.numpy as jnp

from repro.configs.base import AlgoConfig, RLConfig
from repro.core.a3po import staleness
from repro.core.advantages import group_normalized_advantages
from repro.core.objective import (
    Metrics,
    _clip_objective,
    _common_metrics,
    _masked_mean,
    apply_regularizers,
    coupled_ppo_loss,
    decoupled_ppo_loss,
    fused_a3po_loss,
    resolve_alpha,
)


class LossInputs(NamedTuple):
    """Everything an algorithm may see besides the live ``logp``.

    Fields an algorithm did not request via its requires-flags may be
    ``None`` — the training engine only threads what the flags ask for
    through the compiled minibatch scan.
    """

    advantages: jax.Array = None            # [B, T] token advantages
    mask: jax.Array = None                  # [B, T] response mask
    behav_logp: Optional[jax.Array] = None  # log pi_behav [B, T]
    versions: Optional[jax.Array] = None    # behavior versions [B] or [B, T]
    current_version: Any = None             # scalar v(pi_theta)
    prox_logp: Optional[jax.Array] = None   # recomputed prox anchor [B, T]
    entropy: Optional[jax.Array] = None     # per-token entropy [B, T]


@dataclasses.dataclass(frozen=True)
class Algorithm(AlgoConfig):
    """A policy-optimization algorithm: requires-flags + loss + hooks.

    Subclasses are frozen dataclasses whose *fields are the algorithm's
    hyperparameters* (the nested per-algorithm config ``RLConfig.algo``
    holds); the class-level flags are static metadata the engine branches
    on at trace time, never inside the compiled program.
    """

    # registry name — set by @register
    name: ClassVar[str] = "abstract"
    # ---- data requirements (static; read by the training engine) ----
    needs_behav_logp: ClassVar[bool] = True    # behavior logps in the scan
    needs_prox_forward: ClassVar[bool] = False  # explicit prox fwd pass
    needs_versions: ClassVar[bool] = True      # version stamps in the scan
    needs_group_rewards: ClassVar[bool] = True  # grouped reward layout
    # on-policy algorithms get staleness-0 schedules from drivers
    on_policy: ClassVar[bool] = False

    def loss(self, logp: jax.Array, batch: LossInputs, cfg: RLConfig
             ) -> Tuple[jax.Array, Metrics]:
        raise NotImplementedError

    # ---- optional hooks ----
    def advantages(self, rewards: jax.Array, mask: jax.Array,
                   cfg: RLConfig) -> jax.Array:
        """[B] rewards -> [B, T] token advantages. Default: GRPO group
        normalization; algorithms with ``needs_group_rewards = False``
        fall back to batch-level normalization (no group layout)."""
        if self.needs_group_rewards:
            adv = group_normalized_advantages(rewards, cfg.group_size)
        else:
            r = rewards.astype(jnp.float32)
            adv = (r - r.mean()) / (r.std() + 1e-6)
        return adv[:, None] * mask

    def alpha(self, cfg: RLConfig, **kw) -> jax.Array:
        """Prox-interpolation weight; default = the unified schedule
        dispatch (staleness schedules + the kl_adaptive controller)."""
        return resolve_alpha(cfg, **kw)


# ------------------------------------------------------------------ registry
_REGISTRY: Dict[str, Type[Algorithm]] = {}
_ALIASES: Dict[str, str] = {}


def register(name: str, *, aliases: Tuple[str, ...] = ()
             ) -> Callable[[Type[Algorithm]], Type[Algorithm]]:
    """Class decorator: ``@register("name")`` adds an Algorithm subclass
    to the registry (and stamps ``cls.name``)."""
    def deco(cls: Type[Algorithm]) -> Type[Algorithm]:
        assert issubclass(cls, Algorithm), cls
        names = (name,) + tuple(aliases)
        # validate before inserting anything: a collision must leave the
        # registry untouched, not half-registered
        for n in names:
            if n in _REGISTRY:
                raise ValueError(f"algorithm {n!r} already registered "
                                 f"({_REGISTRY[n].__name__})")
        cls.name = name
        for n in names:
            _REGISTRY[n] = cls
        for a in aliases:
            _ALIASES[a] = name
        return cls
    return deco


def unregister(name: str) -> None:
    """Remove an algorithm (by name or alias) and all its aliases
    (test/plugin hygiene)."""
    canonical = _ALIASES.get(name, name)
    cls = _REGISTRY.pop(canonical, None)
    if cls is None:
        return
    for n in [k for k, v in _REGISTRY.items() if v is cls]:
        del _REGISTRY[n]
    for a in [a for a, c in _ALIASES.items() if c == canonical]:
        del _ALIASES[a]


def available() -> List[str]:
    """Canonical registered names (aliases folded in)."""
    return sorted({cls.name for cls in _REGISTRY.values()})


def get_algorithm(name: str, **overrides) -> Algorithm:
    """Instantiate a registered algorithm by name (or alias); keyword
    overrides become hyperparameter fields of the frozen instance."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; available: {available()} "
            f"(aliases: {sorted(_ALIASES)})") from None
    return cls(**overrides)


def resolve_algorithm(spec=None, rl: Optional[RLConfig] = None) -> Algorithm:
    """The one entry point drivers use: Algorithm instance | registry name
    | None (falls back to ``rl.algo``, then the deprecated ``rl.method``
    string, then the paper default ``a3po``)."""
    if isinstance(spec, Algorithm):
        return spec
    if isinstance(spec, str):
        return get_algorithm(spec)
    if spec is not None:
        raise TypeError(f"algo must be an Algorithm or registry name, "
                        f"got {type(spec).__name__}")
    if rl is not None:
        if rl.algo is not None:
            assert isinstance(rl.algo, Algorithm), rl.algo
            return rl.algo
        return get_algorithm(rl.method)
    return get_algorithm("a3po")


def registry_table() -> List[Dict[str, Any]]:
    """One row per registered algorithm: name, aliases, requires-flags,
    hyperparameter fields. Drives ``launch/train.py --algo list`` and the
    README table."""
    rows = []
    for name in available():
        cls = _REGISTRY[name]
        rows.append({
            "name": name,
            "aliases": sorted(a for a, c in _ALIASES.items() if c == name),
            "needs_behav_logp": cls.needs_behav_logp,
            "needs_prox_forward": cls.needs_prox_forward,
            "needs_versions": cls.needs_versions,
            "needs_group_rewards": cls.needs_group_rewards,
            "on_policy": cls.on_policy,
            "fields": {f.name: f.default
                       for f in dataclasses.fields(cls)},
            "doc": ((cls.__doc__ or "").strip().splitlines() or [""])[0],
        })
    return rows


# ----------------------------------------------------------------- built-ins
@register("sync")
@dataclasses.dataclass(frozen=True)
class SyncPPO(Algorithm):
    """Coupled PPO/GRPO (paper Eq. 1): pi_old is IS weight + anchor."""

    needs_versions: ClassVar[bool] = False
    on_policy: ClassVar[bool] = True

    def loss(self, logp, batch, cfg):
        return coupled_ppo_loss(logp, batch.behav_logp, batch.advantages,
                                batch.mask, cfg, batch.entropy)


@register("recompute")
@dataclasses.dataclass(frozen=True)
class RecomputePPO(Algorithm):
    """Decoupled PPO (paper Eq. 2) with the explicitly recomputed proximal
    anchor — the per-step forward pass A-3PO deletes."""

    needs_prox_forward: ClassVar[bool] = True
    needs_versions: ClassVar[bool] = False

    def loss(self, logp, batch, cfg):
        assert batch.prox_logp is not None, \
            "recompute needs the explicit prox forward pass"
        return decoupled_ppo_loss(logp, batch.behav_logp, batch.prox_logp,
                                  batch.advantages, batch.mask, cfg,
                                  batch.entropy)


@register("a3po", aliases=("loglinear",))
@dataclasses.dataclass(frozen=True)
class A3PO(Algorithm):
    """A-3PO (paper Eq. 3-4): log-linear prox approximation through the
    fused Pallas kernel, alpha from the staleness-aware schedule."""

    # overrides cfg.alpha_schedule when set (nested per-algorithm config)
    schedule: Optional[str] = None

    def loss(self, logp, batch, cfg):
        alpha = self.alpha(
            cfg, versions=batch.versions,
            current_version=batch.current_version, logp=logp,
            behav_logp=batch.behav_logp, mask=batch.mask,
            schedule=self.schedule)
        return fused_a3po_loss(logp, batch.behav_logp, alpha,
                               batch.advantages, batch.mask, cfg,
                               batch.entropy)


@register("asympo")
@dataclasses.dataclass(frozen=True)
class ASymPO(Algorithm):
    """Behavior-free asymmetric-scale correction (after ASymPO).

    No behavior logps at all: the surrogate ratio is taken against the
    *detached live policy* (identically 1 in value, policy-gradient in
    derivative), and staleness-induced over-optimism is countered by
    scaling negative-advantage tokens harder than positive ones instead
    of by importance weighting — so rollout workers never need to ship
    ``behav_logp`` (``needs_behav_logp = False``).
    """

    pos_scale: float = 1.0
    neg_scale: float = 1.5

    needs_behav_logp: ClassVar[bool] = False
    needs_versions: ClassVar[bool] = False

    def loss(self, logp, batch, cfg):
        logp = logp.astype(jnp.float32)
        anchor = jax.lax.stop_gradient(logp)
        ratio = jnp.exp(logp - anchor)  # == 1; gradient = d logp
        scale = jnp.where(batch.advantages >= 0.0, self.pos_scale,
                          self.neg_scale).astype(jnp.float32)
        obj, was_clipped = _clip_objective(ratio, scale * batch.advantages,
                                           cfg.clip_eps)
        loss = -_masked_mean(obj, batch.mask)
        metrics = _common_metrics(jnp.ones_like(ratio), ratio, was_clipped,
                                  batch.mask, batch.entropy)
        return apply_regularizers(loss, metrics, logp, anchor, batch.mask,
                                  cfg, batch.entropy)


@register("grpo_mu")
@dataclasses.dataclass(frozen=True)
class MuGRPO(Algorithm):
    """Staleness-gated importance-weight truncation (after mu-GRPO).

    Coupled GRPO ratios, but the importance weight of a token generated
    ``d`` versions ago is truncated at ``1 + clip_eps * mu**d``: fresh
    tokens keep the full PPO clip range, stale tokens cannot be
    up-weighted (their cap decays toward 1), bounding how off-policy a
    gradient any sample can contribute.
    """

    mu: float = 0.7

    def loss(self, logp, batch, cfg):
        logp = logp.astype(jnp.float32)
        behav = batch.behav_logp.astype(jnp.float32)
        d = staleness(batch.versions, batch.current_version)
        if d.ndim == logp.ndim - 1:
            d = d[..., None]
        cap = 1.0 + cfg.clip_eps * (self.mu ** d)
        ratio = jnp.exp(logp - behav)
        trunc = jnp.minimum(ratio, jax.lax.stop_gradient(cap))
        obj, was_clipped = _clip_objective(trunc, batch.advantages,
                                           cfg.clip_eps)
        loss = -_masked_mean(obj, batch.mask)
        metrics = _common_metrics(trunc, ratio, was_clipped, batch.mask,
                                  batch.entropy)
        return apply_regularizers(loss, metrics, logp, behav, batch.mask,
                                  cfg, batch.entropy)


BUILTINS: Tuple[str, ...] = ("sync", "recompute", "a3po", "asympo",
                             "grpo_mu")
