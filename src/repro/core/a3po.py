"""A-3PO: staleness-aware proximal policy approximation (paper §3).

The proximal policy used as the trust-region anchor in decoupled PPO is
*approximated* by log-linear interpolation between the behavior policy and
the live target policy, weighted by a staleness-aware coefficient:

    log pi_prox = alpha * log pi_behav + (1 - alpha) * log pi_theta
    alpha = 0 if d == 0 else 1/d,   d = version(theta) - version(behav)

This is Listing 1 of the paper, in JAX, plus the generalized alpha
schedules we ablate beyond the paper (exp / clipped / const).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RLConfig


def staleness(versions: jax.Array, current_version) -> jax.Array:
    """d = v(pi_theta) - v(pi_behav), clipped at >= 0. [B] or [B,T]."""
    d = jnp.asarray(current_version, jnp.float32) - versions.astype(jnp.float32)
    return jnp.maximum(d, 0.0)


def alpha_from_staleness(d: jax.Array, cfg: Optional[RLConfig] = None,
                         schedule: Optional[str] = None) -> jax.Array:
    """Staleness-aware coefficient alpha (paper Eq. 4 + extensions).

    ``kl_adaptive`` is not a function of staleness alone (it needs the
    behavior/target logps — see ``kl_adaptive_alpha`` and the single
    dispatch point ``core.objective.resolve_alpha``); called with only
    ``d`` it degrades gracefully to the paper's inverse schedule, the
    staleness-only surrogate, instead of raising.
    """
    cfg = cfg or RLConfig()
    schedule = schedule or cfg.alpha_schedule
    fresh = d < 1.0
    if schedule in ("inverse", "kl_adaptive"):  # paper: alpha = 1/d, 0 at d=0
        a = jnp.where(fresh, 0.0, 1.0 / jnp.maximum(d, 1.0))
    elif schedule == "exp":  # alpha = gamma^d (beyond-paper)
        a = jnp.where(fresh, 0.0, cfg.alpha_gamma ** d)
    elif schedule == "clipped":  # 1/d clipped into [lo, hi] (beyond-paper)
        lo, hi = cfg.alpha_clip
        a = jnp.where(fresh, 0.0,
                      jnp.clip(1.0 / jnp.maximum(d, 1.0), lo, hi))
    elif schedule == "const":
        a = jnp.where(fresh, 0.0, cfg.alpha_const)
    else:
        raise ValueError(f"unknown alpha schedule {schedule!r}")
    return a.astype(jnp.float32)


def compute_prox_logp_approximation(
    old_logp: jax.Array,        # log pi_behav  [B, T]
    logprobs: jax.Array,        # log pi_theta  [B, T] (live, will be detached)
    versions: jax.Array,        # behavior policy versions [B] or [B, T]
    current_version,            # scalar int
    cfg: Optional[RLConfig] = None,
) -> jax.Array:
    """Approximate proximal log-probabilities (paper Listing 1).

    The result is stop_gradient'ed: the proximal policy is a *frozen*
    trust-region anchor, exactly like the recomputed one in decoupled PPO.
    Cost: elementwise ops only — no forward pass.
    """
    d = staleness(versions, current_version)
    alpha = alpha_from_staleness(d, cfg)
    if alpha.ndim == old_logp.ndim - 1:
        alpha = alpha[..., None]  # broadcast per-sequence alpha over tokens
    prox = alpha * old_logp.astype(jnp.float32) \
        + (1.0 - alpha) * logprobs.astype(jnp.float32)
    return jax.lax.stop_gradient(prox)


def kl_adaptive_alpha(
    old_logp: jax.Array,        # log pi_behav  [B, T]
    logprobs: jax.Array,        # log pi_theta  [B, T]
    mask: jax.Array,            # [B, T] response mask
    target_kl: float = 0.05,
    alpha_min: float = 0.0,
    alpha_max: float = 1.0,
) -> jax.Array:
    """Beyond-paper: pick alpha per sequence so the anchor sits a *fixed
    KL distance* from the target policy rather than a staleness-scheduled
    fraction. Returns [B, 1], stop_gradient'ed.

    Under the log-linear family, KL(pi_theta || pi_prox) scales ~
    alpha^2 * KL(pi_theta || pi_behav) (quadratic in the interpolation
    weight for small divergences). Solving alpha = sqrt(target / kl_hat)
    keeps the trust region at constant width regardless of how far the
    behavior policy drifted — useful when staleness d is a poor proxy for
    actual policy movement (e.g. tiny learning rates).
    """
    diff = (logprobs - old_logp).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask, axis=-1), 1.0)
    # per-seq KL(pi_theta||pi_behav) estimate from the sampled tokens
    # (k1 estimator on the response region)
    kl_hat = jnp.abs(jnp.sum(diff * mask, axis=-1) / denom)
    alpha = jnp.sqrt(target_kl / jnp.maximum(kl_hat, 1e-8))
    alpha = jnp.clip(alpha, alpha_min, alpha_max)[..., None]
    return jax.lax.stop_gradient(alpha)


def compute_prox_logp_kl_adaptive(
    old_logp: jax.Array,        # log pi_behav  [B, T]
    logprobs: jax.Array,        # log pi_theta  [B, T]
    mask: jax.Array,            # [B, T] response mask
    target_kl: float = 0.05,
    alpha_min: float = 0.0,
    alpha_max: float = 1.0,
) -> jax.Array:
    """KL-adaptive proximal anchor: the log-linear interpolation at the
    per-sequence ``kl_adaptive_alpha`` weight. Stop_gradient'ed."""
    alpha = kl_adaptive_alpha(old_logp, logprobs, mask, target_kl,
                              alpha_min, alpha_max)
    prox = alpha * old_logp.astype(jnp.float32) \
        + (1.0 - alpha) * logprobs.astype(jnp.float32)
    return jax.lax.stop_gradient(prox)
