from repro.core.a3po import (  # noqa: F401
    alpha_from_staleness,
    compute_prox_logp_approximation,
    compute_prox_logp_kl_adaptive,
    kl_adaptive_alpha,
    staleness,
)
from repro.core.advantages import (  # noqa: F401
    broadcast_over_tokens,
    group_normalized_advantages,
)
from repro.core.objective import (  # noqa: F401
    fused_a3po_loss,
    policy_objective,
    resolve_alpha,
)
from repro.core.losses import (  # noqa: F401
    coupled_ppo_loss,
    decoupled_ppo_loss,
    policy_loss,
)
from repro.core.algorithms import (  # noqa: F401
    Algorithm,
    LossInputs,
    available,
    get_algorithm,
    register,
    registry_table,
    resolve_algorithm,
)
