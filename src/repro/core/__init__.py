from repro.core.a3po import (  # noqa: F401
    alpha_from_staleness,
    compute_prox_logp_approximation,
    staleness,
)
from repro.core.advantages import (  # noqa: F401
    broadcast_over_tokens,
    group_normalized_advantages,
)
from repro.core.losses import (  # noqa: F401
    coupled_ppo_loss,
    decoupled_ppo_loss,
    policy_loss,
)
