"""Config dataclasses for models, input shapes, and RL training.

Every assigned architecture is expressed as a ``ModelConfig``; the A-3PO
algorithm settings live in ``RLConfig``. Configs are frozen dataclasses so
they can be hashed into jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (capacity-based top-k routing)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 style Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """A decoder-style LM. ``arch_type`` selects the block wiring."""

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // num_heads
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # Hybrid wiring: an attention(+MLP) block every `attn_every` layers
    # (0 => attention-free / pure SSM; 1 => every layer is attention).
    attn_every: int = 1
    share_attn_params: bool = False  # Zamba2-style shared attention block
    parallel_block: bool = False  # Cohere-style parallel attn+FFN
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # static window; None = full causal
    # Window applied only at the long-context decode shape for full-attention
    # archs (the documented sub-quadratic variant). SSM archs ignore it.
    long_context_window: int = 8192
    tie_embeddings: bool = False
    frontend: Optional[str] = None  # audio | vision (embedding stubs)
    frontend_tokens: int = 0  # patch/frame embeddings prepended to the text
    dtype: str = "bfloat16"
    remat: bool = True  # activation checkpointing across the layer scan

    # ----- derived helpers -------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.attn_every == 0

    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind: 'attn' (attention [+FFN]) or 'ssm'."""
        if self.arch_type in ("ssm",):
            return ("ssm",) * self.num_layers
        if self.arch_type == "hybrid":
            kinds = []
            for i in range(self.num_layers):
                if self.attn_every > 0 and (i % self.attn_every) == (self.attn_every - 1):
                    kinds.append("attn")
                else:
                    kinds.append("ssm")
            return tuple(kinds)
        return ("attn",) * self.num_layers

    def num_params(self) -> int:
        """Analytic parameter count (matches init; used for rooflines)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_attn = sum(1 for k in self.block_kinds() if k == "attn")
        n_ssm = self.num_layers - n_attn
        if self.share_attn_params and n_attn > 0:
            n_attn_unique = 1
        else:
            n_attn_unique = n_attn
        p = 0
        # embeddings (+ output head unless tied) + final norm
        p += self.vocab_size * d
        if not self.tie_embeddings:
            p += self.vocab_size * d
        p += d
        if self.frontend is not None:
            p += d * d  # frontend projector
        # attention blocks
        if n_attn_unique:
            if self.mla is not None:
                m = self.mla
                qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
                per = (
                    d * self.num_heads * qk_dim  # q proj
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)  # down proj
                    + m.kv_lora_rank  # latent norm
                    + m.kv_lora_rank
                    * self.num_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)  # up proj
                    + self.num_heads * m.v_head_dim * d  # out proj
                )
            else:
                per = (
                    d * self.num_heads * hd
                    + 2 * d * self.num_kv_heads * hd
                    + self.num_heads * hd * d
                )
                if self.qkv_bias:
                    per += (self.num_heads + 2 * self.num_kv_heads) * hd
            ffn_per = self._ffn_params()
            n_norms = 1 if self.parallel_block else 2
            p += n_attn_unique * (per + ffn_per + n_norms * d)
        # ssm blocks
        if n_ssm:
            s = self.ssm or SSMConfig()
            din = s.d_inner(d)
            nh = s.num_heads(d)
            conv_dim = din + 2 * s.d_state
            per = (
                d * (2 * din + 2 * s.d_state + nh)  # in_proj -> x,z,B,C,dt
                + s.d_conv * conv_dim + conv_dim  # conv w + b
                + 3 * nh  # A_log, D, dt_bias
                + din  # gated RMSNorm
                + din * d  # out proj
                + d  # block norm
            )
            p += n_ssm * per
        return p

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            m = self.moe
            routed = m.num_experts * 3 * d * m.d_ff_expert
            shared = m.num_shared_experts * 3 * d * m.d_ff_expert
            router = d * m.num_experts
            return routed + shared + router
        return 3 * d * self.d_ff  # SwiGLU

    def num_active_params(self) -> int:
        """Active params/token (MoE counts only top_k + shared experts)."""
        if self.moe is None:
            return self.num_params()
        m = self.moe
        d = self.d_model
        inactive = (m.num_experts - m.top_k) * 3 * d * m.d_ff_expert
        n_moe_layers = sum(1 for k in self.block_kinds() if k == "attn")
        return self.num_params() - n_moe_layers * inactive


@dataclass(frozen=True)
class InputShape:
    """A (seq_len, global_batch, kind) workload point."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class AlgoConfig:
    """Base for per-algorithm hyperparameter blocks.

    Frozen (hashable) so an algorithm rides into jit static args together
    with ``RLConfig``. Concrete policy-optimization algorithms subclass
    this in ``repro.core.algorithms`` and add behavior (loss, hooks) on
    top of their hyperparameter fields; ``RLConfig.algo`` nests one.
    """


@dataclass(frozen=True)
class RLConfig:
    """A-3PO / decoupled-PPO algorithm settings (paper §4.1 defaults).

    Algorithm selection lives in ``algo`` (an ``Algorithm`` instance from
    ``repro.core.algorithms``); the stringly-typed ``method`` field is the
    deprecated pre-registry spelling, kept as a fallback the registry shim
    resolves (``resolve_algorithm``).
    """

    algo: Optional[AlgoConfig] = None  # nested per-algorithm config
    method: str = "loglinear"  # DEPRECATED: a3po/loglinear | recompute | sync
    alpha_schedule: str = "inverse"  # inverse (paper 1/d) | exp | clipped | const
    alpha_const: float = 0.5
    alpha_gamma: float = 0.5  # for exp schedule: alpha = gamma ** d
    alpha_clip: Tuple[float, float] = (0.1, 1.0)
    clip_eps: float = 0.2
    # behavior-weight clipping used by decoupled losses to bound pi_prox/pi_b
    behav_weight_cap: float = 5.0
    entropy_coef: float = 0.0
    # weight of the k1 KL(pi_theta || anchor) penalty added to every
    # algorithm's loss (the anchor is each algorithm's trust-region
    # reference: behavior, recomputed prox, or the log-linear prox)
    kl_coef: float = 0.0
    group_size: int = 4  # samples per prompt (group reward normalization)
    num_minibatches: int = 4  # gradient updates per training step
    learning_rate: float = 8.5e-6
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    weight_decay: float = 0.0
    max_grad_norm: float = 1.0
    max_staleness: int = 4  # AReaL-style bounded staleness gate
    temperature: float = 1.0
    top_p: float = 1.0


def reduced(cfg: ModelConfig, *, num_layers: int = 2, d_model: int = 256,
            vocab_size: int = 512) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    head_dim = 64
    num_heads = max(d_model // head_dim, 1)
    num_kv = max(1, min(cfg.num_kv_heads, num_heads))
    # keep the kv:q ratio flavour (MQA stays MQA, MHA stays MHA)
    if cfg.num_kv_heads == cfg.num_heads:
        num_kv = num_heads
    elif cfg.num_kv_heads == 1:
        num_kv = 1
    else:
        num_kv = max(1, num_heads // 2)
    changes = dict(
        name=cfg.name + "-reduced",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=4 * d_model,
        vocab_size=vocab_size,
        remat=False,
    )
    if cfg.moe is not None:
        # capacity_factor 4.0: drop-free routing at smoke scale so the
        # decode-vs-full consistency check is exact
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_ff_expert=2 * d_model,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            capacity_factor=4.0)
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(kv_lora_rank=64, qk_nope_head_dim=32,
                                   qk_rope_head_dim=16, v_head_dim=32)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32,
                                             chunk_size=32)
    if cfg.arch_type == "hybrid":
        changes["num_layers"] = max(num_layers, cfg.attn_every)
    if cfg.frontend is not None:
        changes["frontend_tokens"] = 8
    return dataclasses.replace(cfg, **changes)
