"""Mamba2-370m [arXiv:2405.21060].

Attention-free SSD (state-space duality) stack: 48L, d_model=1024,
d_state=128, expand=2 (d_inner=2048, 32 SSD heads of dim 64), vocab=50280.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn_every=0,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256),
    tie_embeddings=True,
)
