"""Granite 34B code model [arXiv:2405.04324].

Llama-arch dense decoder with MQA: 88L, d_model=6144, 48 heads (kv=1),
d_ff=24576, vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    arch_type="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=True,  # granite code models use attention biases
    rope_theta=10_000_000.0,
)
