"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-v01 family].

Dense GQA decoder: 64L, d_model=12288, 96 heads (kv=8), d_ff=33792,
vocab=256000. Cohere-style parallel attention+FFN block, no biases,
tied embeddings (Cohere ties input/output embeddings).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    arch_type="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    parallel_block=True,
    qkv_bias=False,
    tie_embeddings=True,
    rope_theta=75_000_000.0,
)
