"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import (
    codeqwen1_5_7b,
    command_r_plus_104b,
    deepseek_coder_33b,
    deepseek_v2_lite_16b,
    granite_34b,
    llava_next_mistral_7b,
    mamba2_370m,
    musicgen_large,
    paper_models,
    qwen3_moe_30b_a3b,
    zamba2_1_2b,
)
from repro.configs.base import ModelConfig, reduced

# The 10 assigned architectures (public-literature pool).
ASSIGNED: Dict[str, ModelConfig] = {
    "command-r-plus-104b": command_r_plus_104b.CONFIG,
    "granite-34b": granite_34b.CONFIG,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b.CONFIG,
    "musicgen-large": musicgen_large.CONFIG,
    "llava-next-mistral-7b": llava_next_mistral_7b.CONFIG,
    "mamba2-370m": mamba2_370m.CONFIG,
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
    "deepseek-coder-33b": deepseek_coder_33b.CONFIG,
    "codeqwen1.5-7b": codeqwen1_5_7b.CONFIG,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b.CONFIG,
}

# Paper experiment + toy models.
EXTRA: Dict[str, ModelConfig] = {
    "qwen2.5-1.5b": paper_models.QWEN25_1_5B,
    "qwen3-8b": paper_models.QWEN3_8B,
    "toy-20m": paper_models.TOY_20M,
    "toy-2m": paper_models.TOY_2M,
}

REGISTRY: Dict[str, ModelConfig] = {**ASSIGNED, **EXTRA}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-reduced"):
        return reduced(get_config(name[: -len("-reduced")]))
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs(assigned_only: bool = False) -> List[str]:
    return sorted(ASSIGNED if assigned_only else REGISTRY)
