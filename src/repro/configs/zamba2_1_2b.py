"""Zamba2-1.2B [arXiv:2411.15242].

Hybrid Mamba2 backbone with a *shared* attention(+MLP) block applied
periodically: 38L, d_model=2048, attn 32 heads (MHA kv=32), d_ff=8192,
ssm_state=64, vocab=32000. We wire the shared block every 6th layer
(6 applications, one parameter set), matching Zamba2's shared-block design.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    attn_every=6,
    share_attn_params=True,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256),
    tie_embeddings=True,
)
