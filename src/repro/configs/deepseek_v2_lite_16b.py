"""DeepSeek-V2-Lite 16B [arXiv:2405.04434].

MoE decoder with Multi-head Latent Attention: 27L, d_model=2048, 16 heads,
MLA kv_lora_rank=512 (qk_nope=128, qk_rope=64, v=128), 64 routed experts
top-6 + 2 shared experts with per-expert d_ff=1408, vocab=102400.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MLA: all heads read the shared latent; kept for spec
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
)
