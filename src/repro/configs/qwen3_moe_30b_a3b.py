"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

MoE decoder: 48L, d_model=2048, 32 heads (kv=4, head_dim=128), 128 experts
top-8 with per-expert d_ff=768, vocab=151936. No shared experts.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,  # per-expert width (kept for reference; MoEConfig governs)
    vocab_size=151936,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    rope_theta=1_000_000.0,
)
