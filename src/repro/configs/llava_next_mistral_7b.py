"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

VLM whose language model is Mistral-7B: 32L, d_model=4096, 32 heads (kv=8),
d_ff=14336, vocab=32000. The ViT/CLIP vision tower + projector are stubbed
per assignment; anyres tiling yields up to 2880 patch embeddings which
``input_specs`` provides precomputed and the model prepends to the text.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    frontend="vision",
    frontend_tokens=2880,  # anyres: 576 base + 4 x 576 tiles
    rope_theta=1_000_000.0,
)
