"""The paper's own experiment models (§4.1) plus toy models for CPU runs.

Setup 1: Qwen2.5-1.5B-Instruct on GSM8K.
Setup 2: Qwen3-8B on DAPO-Math-17k.

``toy-*`` configs drive the end-to-end CPU examples / integration tests.
"""
from repro.configs.base import ModelConfig

QWEN25_1_5B = ModelConfig(
    name="qwen2.5-1.5b",
    arch_type="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

QWEN3_8B = ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    rope_theta=1_000_000.0,
)

# ~20M params: the end-to-end RL example model (trainable on CPU).
TOY_20M = ModelConfig(
    name="toy-20m",
    arch_type="dense",
    num_layers=4,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=1024,
    vocab_size=64,
    tie_embeddings=True,
    remat=False,
)

# ~2M params: fast integration-test model.
TOY_2M = ModelConfig(
    name="toy-2m",
    arch_type="dense",
    num_layers=2,
    d_model=128,
    num_heads=2,
    num_kv_heads=1,
    head_dim=64,
    d_ff=512,
    vocab_size=64,
    tie_embeddings=True,
    remat=False,
)
