"""MusicGen-large decoder [arXiv:2306.05284].

Decoder-only transformer over EnCodec tokens: 48L, d_model=2048, 32 heads
(MHA: kv=32), d_ff=8192, vocab=2048 (codebook size). The EnCodec conv
frontend is stubbed per assignment: ``input_specs`` supplies precomputed
frame embeddings which are prepended as the conditioning prefix.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio",
    frontend_tokens=512,  # conditioning frames (text/melody embedding stub)
    qkv_bias=False,
)
