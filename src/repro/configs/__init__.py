from repro.configs.base import (  # noqa: F401
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RLConfig,
    SHAPES,
    SSMConfig,
    reduced,
)
