"""Bounded rollout queue with staleness filtering (AReaL-style gate)."""
from __future__ import annotations

import queue
import threading
from typing import List, Optional

from repro.rollout.engine import RolloutBatch


class QueueClosed(RuntimeError):
    """Push/pop against a closed ``RolloutQueue`` — a dead peer raises
    instead of blocking forever."""


class RolloutQueue:
    """Thread-safe FIFO of rollout batches with a bounded-staleness gate.

    ``pop_fresh`` drops batches whose behavior version is more than
    ``max_staleness`` behind — the same data-discard policy AReaL applies to
    keep off-policyness bounded.

    Fault tolerance: ``close()`` flips a ``closed`` flag; subsequent pushes
    and pops raise ``QueueClosed`` (pops drain remaining items first), and
    blocked pops wake up at their next poll tick. ``pop``/``pop_fresh``
    raise ``TimeoutError`` after ``timeout`` seconds, so a consumer facing
    a dead producer fails loudly instead of deadlocking (the orchestrator
    pairs this with ``resilience.supervisor.pop_with_health``).
    """

    # closed-flag poll interval for blocking pops
    _POLL_S = 0.25

    def __init__(self, capacity: int = 16, max_staleness: int = 4):
        self._q: "queue.Queue[RolloutBatch]" = queue.Queue(maxsize=capacity)
        self.capacity = capacity
        self.max_staleness = max_staleness
        self.dropped = 0
        self._lock = threading.Lock()
        self._closed = threading.Event()

    @property
    def closed(self) -> bool:
        return self._closed.is_set()

    def close(self) -> None:
        """Mark the queue dead (producer or consumer going away)."""
        self._closed.set()

    def push(self, batch: RolloutBatch, timeout: Optional[float] = None
             ) -> bool:
        """False on a full queue (back-pressure); raises ``QueueClosed``
        once the queue is closed."""
        if self.closed:
            raise QueueClosed("push to closed RolloutQueue")
        try:
            self._q.put(batch, timeout=timeout)
            return True
        except queue.Full:
            return False

    def pop(self, timeout: Optional[float] = None) -> RolloutBatch:
        """One batch, no staleness gate. Raises ``TimeoutError`` after
        ``timeout`` seconds and ``QueueClosed`` when the queue is closed
        and drained (pending items are still delivered)."""
        deadline = None if timeout is None else \
            threading.TIMEOUT_MAX if timeout < 0 else timeout
        waited = 0.0
        while True:
            if self.closed:
                try:
                    return self._q.get_nowait()
                except queue.Empty:
                    raise QueueClosed("pop from closed, drained "
                                      "RolloutQueue") from None
            step = self._POLL_S if deadline is None \
                else min(self._POLL_S, max(deadline - waited, 0.0))
            try:
                return self._q.get(timeout=step)
            except queue.Empty:
                waited += step
                if deadline is not None and waited >= deadline:
                    raise TimeoutError(
                        f"RolloutQueue.pop timed out after {waited:.1f}s"
                    ) from None

    def pop_fresh(self, current_version: int, n: int = 1,
                  timeout: float = 30.0) -> List[RolloutBatch]:
        """Blocking pop of ``n`` sufficiently-fresh batches.

        ``timeout`` bounds the whole call (not per item); stale batches
        are dropped and counted without resetting the clock.
        """
        import time

        out: List[RolloutBatch] = []
        t0 = time.perf_counter()
        while len(out) < n:
            remaining = None if timeout is None \
                else timeout - (time.perf_counter() - t0)
            if remaining is not None and remaining <= 0:
                raise TimeoutError(
                    f"RolloutQueue.pop_fresh: {len(out)}/{n} fresh batches "
                    f"within {timeout:.1f}s")
            batch = self.pop(timeout=remaining)
            # min_version: with per-token stamps (interruptible serving)
            # the *oldest* token in the batch decides its staleness
            if current_version - batch.min_version() > self.max_staleness:
                with self._lock:
                    self.dropped += 1
                continue
            out.append(batch)
        return out

    def qsize(self) -> int:
        return self._q.qsize()

    @property
    def depth_fraction(self) -> float:
        """Queue fullness in [0, 1] — the scheduler's backpressure signal."""
        return self._q.qsize() / self.capacity if self.capacity else 0.0
