"""Bounded rollout queue with staleness filtering (AReaL-style gate)."""
from __future__ import annotations

import queue
import threading
from typing import List, Optional

from repro.rollout.engine import RolloutBatch


class RolloutQueue:
    """Thread-safe FIFO of rollout batches with a bounded-staleness gate.

    ``pop_fresh`` drops batches whose behavior version is more than
    ``max_staleness`` behind — the same data-discard policy AReaL applies to
    keep off-policyness bounded.
    """

    def __init__(self, capacity: int = 16, max_staleness: int = 4):
        self._q: "queue.Queue[RolloutBatch]" = queue.Queue(maxsize=capacity)
        self.capacity = capacity
        self.max_staleness = max_staleness
        self.dropped = 0
        self._lock = threading.Lock()

    def push(self, batch: RolloutBatch, timeout: Optional[float] = None
             ) -> bool:
        try:
            self._q.put(batch, timeout=timeout)
            return True
        except queue.Full:
            return False

    def pop_fresh(self, current_version: int, n: int = 1,
                  timeout: float = 30.0) -> List[RolloutBatch]:
        """Blocking pop of ``n`` sufficiently-fresh batches."""
        out: List[RolloutBatch] = []
        while len(out) < n:
            batch = self._q.get(timeout=timeout)
            # min_version: with per-token stamps (interruptible serving)
            # the *oldest* token in the batch decides its staleness
            if current_version - batch.min_version() > self.max_staleness:
                with self._lock:
                    self.dropped += 1
                continue
            out.append(batch)
        return out

    def qsize(self) -> int:
        return self._q.qsize()

    @property
    def depth_fraction(self) -> float:
        """Queue fullness in [0, 1] — the scheduler's backpressure signal."""
        return self._q.qsize() / self.capacity if self.capacity else 0.0
