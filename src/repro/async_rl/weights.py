"""Versioned weight store — the trainer->rollout weight-sync channel.

In AReaL this is an NCCL broadcast between GPU pools; here it is a lock-
protected (version, params) cell. On a real multi-pod TPU deployment the
publish is a ``jax.device_put`` onto the rollout pod slice's mesh (see
launch/train.py).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional, Tuple


class WeightStore:
    def __init__(self, params: Any, version: int = 0):
        self._lock = threading.Lock()
        self._params = params
        self._version = version
        self._listeners: List[Callable[[int], None]] = []

    def subscribe(self, fn: Callable[[int], None]) -> None:
        """Register a publish listener (serving control plane interrupts).

        ``fn(version)`` is invoked synchronously after every publish, from
        the publisher's thread and outside the lock — listeners must be
        cheap and thread-safe (the InterruptController just sets an event).
        """
        with self._lock:
            self._listeners.append(fn)

    def publish(self, params: Any, version: int) -> None:
        with self._lock:
            self._params = params
            self._version = version
            listeners = list(self._listeners)
        for fn in listeners:
            fn(version)

    def latest(self) -> Tuple[Any, int]:
        with self._lock:
            return self._params, self._version

    @property
    def version(self) -> int:
        with self._lock:
            return self._version
