"""Async RL orchestration: decoupled rollout + training engines.

Two operating modes:

* ``AsyncOrchestrator`` — real threads: a rollout worker continuously pulls
  the latest weights, generates groups, and pushes version-stamped batches;
  the trainer consumes fresh batches and publishes new weights. This is the
  AReaL architecture in miniature (on one host the engines time-share the
  device; on the production mesh they own disjoint pod slices).

* ``simulate_async`` — deterministic single-thread simulation with an
  explicit staleness schedule. Used by tests and by the sync-vs-async
  benchmarks (reproducible, schedule-model timing).

Fault tolerance (``repro.resilience``): both modes accept a
``ResilienceConfig``. The rollout worker runs under a ``SupervisedWorker``
(heartbeats, capture, bounded seeded restarts), queue pops go through
``pop_with_health`` (a dead producer raises instead of deadlocking the
trainer), weight publishes retry with backoff, a ``TrainGuard`` applies
skip/rollback policies to non-finite updates, periodic crash-consistent
checkpoints capture params/opt/step/RNG/weight-version, and a seeded
``FaultPlan`` can inject crashes/stalls/NaNs at any of those sites.
``StepRecord.resilience`` snapshots the ``resilience_*`` counters.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.async_rl.buffer import RolloutQueue
from repro.async_rl.weights import WeightStore
from repro.data.tasks import ArithmeticTask
from repro.obs.tracing import (
    flow_end,
    flow_start,
    span,
    step_annotation,
)
from repro.rollout.engine import RolloutEngine
from repro.training.trainer import (
    TrainState,
    Trainer,
    assemble_train_batch,
)


@dataclasses.dataclass
class StepRecord:
    step: int
    reward: float
    loss: float
    entropy: float
    iw_max: float
    iw_min: float
    clipped_tokens: float
    staleness_mean: float
    prox_time_s: float
    rollout_time_s: float
    train_time_s: float
    wall_time_s: float
    eval_reward: Optional[float] = None  # held-out eval (when scheduled)
    # serving control-plane snapshot (staleness distribution, prefix-cache
    # hit rate, queue delay, page utilization, interrupt counts)
    serving: Optional[Dict[str, float]] = None
    # training-engine telemetry: response tokens updated this step and
    # device->host transfers the compiled step performed (1 for the scan
    # engine; +1 for the explicit prox pass of the 'recompute' baseline)
    train_tokens: float = 0.0
    host_syncs: float = 0.0
    # resilience_* counter snapshot (faults injected, worker restarts,
    # skipped updates, checkpoint saves/restores) when a ResilienceConfig
    # is active
    resilience: Optional[Dict[str, float]] = None


def _rollout_once(engine: RolloutEngine, task: ArithmeticTask,
                  params, version: int, n_prompts: int, group: int, key):
    batch = task.sample(n_prompts)
    prompts = np.repeat(batch.prompts, group, axis=0)
    lengths = np.repeat(batch.prompt_lengths, group)
    answers = [a for a in batch.answers for _ in range(group)]
    rb = engine.generate(params, prompts, lengths, key, version=version)
    completions = engine.completions(rb)
    rewards = task.rewards(completions, answers)
    return rb, rewards


def _inject_nan_reward(rewards: np.ndarray, faults) -> np.ndarray:
    """``nan_grad`` fault: poison one reward (seeded choice). Advantages,
    loss, and every gradient leaf go non-finite — exactly what the
    on-device guard must catch."""
    spec = faults.check("nan_grad") if faults is not None else None
    if spec is None:
        return rewards
    rewards = np.asarray(rewards, np.float32).copy()
    rewards[int(faults.rng.integers(len(rewards)))] = np.nan
    return rewards


def _resilience_snapshot(resilience) -> Optional[Dict[str, float]]:
    if resilience is None:
        return None
    from repro.resilience.faults import resilience_snapshot
    return resilience_snapshot()


class AsyncOrchestrator:
    """Thread-decoupled rollout/training loop.

    ``algo`` is an ``Algorithm`` instance or registry name
    (``core.algorithms``); dispatch is entirely the Trainer's — the
    orchestrator never branches on it. ``resilience`` is an optional
    ``repro.resilience.ResilienceConfig``."""

    def __init__(self, cfg: ModelConfig, rl: RLConfig, task: ArithmeticTask,
                 algo="a3po", n_prompts: int = 16,
                 max_new_tokens: int = 8, queue_capacity: int = 4,
                 seed: int = 0, use_control_plane: bool = False,
                 serve_kwargs: Optional[Dict] = None,
                 decode_horizon: int = 8,
                 resilience=None):
        self.cfg, self.rl, self.task = cfg, rl, task
        self.n_prompts = n_prompts
        self.max_new_tokens = max_new_tokens
        self.engine = RolloutEngine(cfg, rl, max_new_tokens)
        self.resilience = resilience
        guard = resilience.guard if resilience is not None else None
        self.trainer = Trainer(
            cfg, rl, algo,
            skip_nonfinite=(guard is not None and guard.policy != "off"))
        self.algo = self.trainer.algo
        self.guard = guard
        self.queue = RolloutQueue(queue_capacity, rl.max_staleness)
        self.seed = seed
        self._stop = threading.Event()
        self._rollout_times: List[float] = []
        # serving control plane (interruptible continuous batching with a
        # radix prefix cache) instead of the run-to-completion engine
        self.use_control_plane = use_control_plane
        # decode horizon for the continuous-batching engine: tokens per
        # compiled serving launch (host drains once per horizon). Weight
        # publishes are absorbed at horizon boundaries; per-token version
        # stamps stay truthful (first horizon token carries the version
        # that produced its logits).
        self.decode_horizon = decode_horizon
        self._serve_kwargs = serve_kwargs or {}
        self.control_plane = None
        self.worker = None  # the SupervisedWorker of the last run()

    @property
    def _faults(self):
        return self.resilience.faults if self.resilience is not None \
            else None

    def _build_control_plane(self, store: WeightStore):
        from repro.rollout.continuous import ContinuousBatchingEngine
        from repro.serving import (AdmissionScheduler, SchedulerConfig,
                                   ServingControlPlane)
        kw = dict(max_seqs=self.n_prompts * self.rl.group_size,
                  block_size=8, n_blocks=512, max_blocks_per_seq=16,
                  decode_horizon=self.decode_horizon)
        kw.update(self._serve_kwargs)
        srv = ContinuousBatchingEngine(self.cfg, rl=self.rl, **kw)
        return ServingControlPlane(
            srv, store,
            AdmissionScheduler(SchedulerConfig(d_max=self.rl.max_staleness)),
            rollout_queue=self.queue, faults=self._faults)

    def _rollout_once_cp(self, key):
        """Group rollout through the serving control plane: GRPO members
        share one prefill via the radix cache, and weight publishes landing
        mid-batch are absorbed with per-token version stamps."""
        batch = self.task.sample(self.n_prompts)
        group = self.rl.group_size
        prompts = np.repeat(batch.prompts, group, axis=0)
        lengths = np.repeat(batch.prompt_lengths, group)
        answers = [a for a in batch.answers for _ in range(group)]
        rb = self.control_plane.generate_batch(
            prompts, lengths, key, max_new=self.max_new_tokens)
        completions = self.engine.completions(rb)
        rewards = self.task.rewards(completions, answers)
        return rb, rewards

    def _rollout_worker(self, ctx, store: WeightStore):
        """Supervised worker body: loops until told to stop, heartbeats
        every iteration, raises on injected crashes (the supervisor
        captures + restarts)."""
        from repro.async_rl.buffer import QueueClosed

        faults = self._faults
        key = jax.random.PRNGKey(self.seed + 1)
        while not ctx.should_stop():
            ctx.heartbeat()
            if faults is not None:
                faults.maybe_crash("rollout_crash")
                stall = faults.check("queue_stall")
                if stall is not None and stall.magnitude > 0:
                    time.sleep(stall.magnitude)
            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            if self.control_plane is not None:
                rb, rewards = self._rollout_once_cp(sub)
            else:
                params, version = store.latest()
                with span("rollout", version=version) as sp:
                    rb, rewards = _rollout_once(
                        self.engine, self.task, params, version,
                        self.n_prompts, self.rl.group_size, sub)
                    sp.set(reward_mean=float(np.mean(rewards)))
                    # close the publish->rollout flow arrow: first
                    # rollout generated under the published version
                    flow_end("publish", version)
            self._rollout_times.append(time.perf_counter() - t0)
            rb.rewards = rewards  # piggyback
            try:
                if not self.queue.push(rb, timeout=1.0):
                    continue  # queue full — back-pressure
            except QueueClosed:
                return  # consumer went away: clean exit

    def _pop_batches(self, state: TrainState):
        """One fresh batch, deadlock-free when supervised."""
        if self.resilience is not None:
            from repro.resilience.supervisor import pop_with_health
            return pop_with_health(
                self.queue, self.worker, int(state.version), n=1,
                deadline_s=self.resilience.pop_deadline_s)
        return self.queue.pop_fresh(int(state.version), n=1)

    def _checkpoint(self, step_done: int, state: TrainState) -> None:
        res = self.resilience
        if res is not None and res.maybe_checkpoint(step_done):
            res.checkpointer.save(
                step_done + 1, state,
                task_rng_state=self.task.rng.bit_generator.state,
                extra={"algo": self.algo.name, "mode": "async"})

    def _apply_guard(self, state: TrainState, m: Dict[str, float]
                     ) -> TrainState:
        """Host-side guard policy on the step's (already transferred)
        metrics. On rollback the restored params/opt replace the live
        state but the version counter keeps advancing — staleness stamps
        stay monotonic for the scheduler."""
        if self.guard is None:
            return state
        verdict = self.guard.after_step(m)
        if verdict.action == "rollback" and self.resilience is not None \
                and self.resilience.checkpointer is not None:
            info = self.resilience.checkpointer.restore_latest()
            if info is not None:
                state = TrainState(info.state.params, info.state.opt,
                                   state.version)
        return state

    def run(self, state: TrainState, num_steps: int,
            run_logger=None, start_step: int = 0
            ) -> (TrainState, List[StepRecord]):
        """Drive training steps ``start_step..num_steps-1`` against the
        live rollout worker. ``run_logger`` (``obs.runlog.RunLogger``)
        gets exactly one JSONL step record per training step."""
        from repro.resilience.supervisor import SupervisedWorker

        res = self.resilience
        self._stop.clear()
        store = WeightStore(state.params, int(state.version))
        publisher = None
        if res is not None:
            from repro.resilience.publish import ResilientPublisher
            publisher = ResilientPublisher(
                store, faults=res.faults,
                max_retries=res.publish_max_retries, seed=res.seed)
        if self.use_control_plane:
            self.control_plane = self._build_control_plane(store)
        self.worker = SupervisedWorker(
            "rollout-worker", self._rollout_worker, args=(store,),
            max_restarts=(res.max_worker_restarts if res is not None
                          else 0),
            heartbeat_timeout_s=(res.heartbeat_timeout_s if res is not None
                                 else 60.0),
            seed=(res.seed if res is not None else 0),
            stop_event=self._stop)
        t_start = time.perf_counter()
        self.worker.start()
        records: List[StepRecord] = []
        faults = self._faults
        try:
            for step in range(start_step, num_steps):
                if faults is not None:
                    faults.maybe_crash("train_crash")
                with step_annotation(step):
                    batches = self._pop_batches(state)
                    rewards = np.concatenate([b.rewards for b in batches])
                    rewards = _inject_nan_reward(rewards, faults)
                    tb = assemble_train_batch(batches, rewards)
                    t0 = time.perf_counter()
                    with span("train_step", step=step):
                        state, m = self.trainer.step(state, tb)
                    train_t = time.perf_counter() - t0
                    state = self._apply_guard(state, m)
                    version = int(state.version)
                    with span("weight_publish", version=version):
                        if publisher is not None:
                            publisher.publish(state.params, version)
                        else:
                            store.publish(state.params, version)
                        # open the publish->resume flow arrow (closed by
                        # the first rollout/serving step under `version`)
                        flow_start("publish", version)
                self._checkpoint(step, state)
                serving = (self.control_plane.metrics.snapshot()
                           if self.control_plane is not None else None)
                records.append(StepRecord(
                    step=step, reward=m["reward_mean"], loss=m["loss"],
                    entropy=m.get("entropy", 0.0), iw_max=m["iw_max"],
                    iw_min=m["iw_min"], clipped_tokens=m["clipped_tokens"],
                    staleness_mean=m["staleness_mean"],
                    prox_time_s=m["prox_time_s"],
                    rollout_time_s=(np.mean(self._rollout_times[-3:])
                                    if self._rollout_times else 0.0),
                    train_time_s=train_t,
                    wall_time_s=time.perf_counter() - t_start,
                    serving=serving,
                    train_tokens=m.get("tokens", 0.0),
                    host_syncs=m.get("host_syncs", 0.0),
                    resilience=_resilience_snapshot(res)))
                if run_logger is not None:
                    run_logger.log_step(records[-1])
        finally:
            self._stop.set()
            self.queue.close()
            self.worker.stop(timeout=10.0)
        return state, records


def simulate_async(cfg: ModelConfig, rl: RLConfig, task: ArithmeticTask,
                   algo, num_steps: int, *,
                   n_prompts: int = 8, max_new_tokens: int = 8,
                   staleness: int = 1, seed: int = 0,
                   init_state: Optional[TrainState] = None,
                   record_hook: Optional[Callable[[int, Dict], None]] = None,
                   eval_every: int = 0,
                   eval_fn: Optional[Callable] = None,
                   num_microbatches: int = 1,
                   run_logger=None,
                   resilience=None,
                   resume=None,
                   ) -> (TrainState, List[StepRecord]):
    """Deterministic async simulation: behavior policy lags ``staleness``
    versions behind (0 == synchronous on-policy). ``algo`` is an
    ``Algorithm`` or registry name. ``eval_fn(params)`` is invoked every
    ``eval_every`` steps (the paper's held-out eval worker, Fig. 3);
    results land in ``StepRecord.eval_reward``. ``run_logger``
    (``obs.runlog.RunLogger``) gets one JSONL step record per step.

    ``resilience`` (``repro.resilience.ResilienceConfig``) enables
    periodic checkpoints, guard policies, and fault injection;
    ``resume`` (``repro.resilience.ResumeInfo``, e.g. from
    ``CheckpointManager.restore_latest()``) continues a checkpointed run
    — params, Adam state, weight version, rollout PRNG key, staleness
    history, and the task's RNG stream are all restored, so the resumed
    run is bit-identical to the uninterrupted one from that step.
    """
    guard = resilience.guard if resilience is not None else None
    faults = resilience.faults if resilience is not None else None
    engine = RolloutEngine(cfg, rl, max_new_tokens)
    trainer = Trainer(
        cfg, rl, algo, num_microbatches=num_microbatches,
        skip_nonfinite=(guard is not None and guard.policy != "off"))
    start_step = 0
    history: deque = deque(maxlen=staleness + 1)
    if resume is not None:
        state = resume.state
        start_step = resume.step
        key = resume.key if resume.key is not None \
            else jax.random.PRNGKey(seed)
        if resume.history is not None:
            for p, v in resume.history:
                history.append((p, v))
        else:
            history.append((state.params, int(state.version)))
        if resume.task_rng_state is not None:
            task.rng.bit_generator.state = resume.task_rng_state
    else:
        key = jax.random.PRNGKey(seed)
        state = init_state or trainer.init_state(jax.random.PRNGKey(seed + 7))
        history.append((state.params, int(state.version)))
    records: List[StepRecord] = []
    t_start = time.perf_counter()
    for step in range(start_step, num_steps):
        if faults is not None:
            faults.maybe_crash("train_crash")
        behav_params, behav_version = history[0]
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        with span("rollout", step=step, version=behav_version) as sp:
            rb, rewards = _rollout_once(engine, task, behav_params,
                                        behav_version, n_prompts,
                                        rl.group_size, sub)
            sp.set(reward_mean=float(np.mean(rewards)))
            # close the publish->rollout staleness arrow: the simulated
            # behavior policy first acts `staleness` steps after publish
            flow_end("publish", behav_version)
        rollout_t = time.perf_counter() - t0
        rewards = _inject_nan_reward(rewards, faults)
        tb = assemble_train_batch([rb], rewards)
        t0 = time.perf_counter()
        with step_annotation(step), span("train_step", step=step,
                                         staleness=staleness):
            state, m = trainer.step(state, tb)
        train_t = time.perf_counter() - t0
        if guard is not None:
            verdict = guard.after_step(m)
            if verdict.action == "rollback" and resilience is not None \
                    and resilience.checkpointer is not None:
                info = resilience.checkpointer.restore_latest()
                if info is not None:
                    state = TrainState(info.state.params, info.state.opt,
                                       state.version)
                    history.clear()
        version = int(state.version)
        with span("weight_publish", version=version):
            history.append((state.params, version))
            flow_start("publish", version)
        if resilience is not None and resilience.maybe_checkpoint(step):
            resilience.checkpointer.save(
                step + 1, state, key=key, history=list(history),
                task_rng_state=task.rng.bit_generator.state,
                extra={"algo": trainer.algo.name, "mode": "sim",
                       "staleness": staleness})
        rec = StepRecord(
            step=step, reward=m["reward_mean"], loss=m["loss"],
            entropy=m.get("entropy", 0.0), iw_max=m["iw_max"],
            iw_min=m["iw_min"], clipped_tokens=m["clipped_tokens"],
            staleness_mean=m["staleness_mean"], prox_time_s=m["prox_time_s"],
            rollout_time_s=rollout_t, train_time_s=train_t,
            wall_time_s=time.perf_counter() - t_start,
            train_tokens=m.get("tokens", 0.0),
            host_syncs=m.get("host_syncs", 0.0),
            resilience=_resilience_snapshot(resilience))
        if eval_fn and eval_every and (step + 1) % eval_every == 0:
            rec.eval_reward = float(eval_fn(state.params))
        records.append(rec)
        if run_logger is not None:
            run_logger.log_step(rec)
        if record_hook:
            record_hook(step, m)
    return state, records
