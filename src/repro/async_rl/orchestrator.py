"""Async RL orchestration: decoupled rollout + training engines.

Two operating modes:

* ``AsyncOrchestrator`` — real threads: a rollout worker continuously pulls
  the latest weights, generates groups, and pushes version-stamped batches;
  the trainer consumes fresh batches and publishes new weights. This is the
  AReaL architecture in miniature (on one host the engines time-share the
  device; on the production mesh they own disjoint pod slices).

* ``simulate_async`` — deterministic single-thread simulation with an
  explicit staleness schedule. Used by tests and by the sync-vs-async
  benchmarks (reproducible, schedule-model timing).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.async_rl.buffer import RolloutQueue
from repro.async_rl.weights import WeightStore
from repro.data.tasks import ArithmeticTask
from repro.obs.tracing import (
    flow_end,
    flow_start,
    span,
    step_annotation,
)
from repro.rollout.engine import RolloutEngine
from repro.training.trainer import (
    TrainState,
    Trainer,
    assemble_train_batch,
)


@dataclasses.dataclass
class StepRecord:
    step: int
    reward: float
    loss: float
    entropy: float
    iw_max: float
    iw_min: float
    clipped_tokens: float
    staleness_mean: float
    prox_time_s: float
    rollout_time_s: float
    train_time_s: float
    wall_time_s: float
    eval_reward: Optional[float] = None  # held-out eval (when scheduled)
    # serving control-plane snapshot (staleness distribution, prefix-cache
    # hit rate, queue delay, page utilization, interrupt counts)
    serving: Optional[Dict[str, float]] = None
    # training-engine telemetry: response tokens updated this step and
    # device->host transfers the compiled step performed (1 for the scan
    # engine; +1 for the explicit prox pass of the 'recompute' baseline)
    train_tokens: float = 0.0
    host_syncs: float = 0.0


def _rollout_once(engine: RolloutEngine, task: ArithmeticTask,
                  params, version: int, n_prompts: int, group: int, key):
    batch = task.sample(n_prompts)
    prompts = np.repeat(batch.prompts, group, axis=0)
    lengths = np.repeat(batch.prompt_lengths, group)
    answers = [a for a in batch.answers for _ in range(group)]
    rb = engine.generate(params, prompts, lengths, key, version=version)
    completions = engine.completions(rb)
    rewards = task.rewards(completions, answers)
    return rb, rewards


class AsyncOrchestrator:
    """Thread-decoupled rollout/training loop.

    ``algo`` is an ``Algorithm`` instance or registry name
    (``core.algorithms``); dispatch is entirely the Trainer's — the
    orchestrator never branches on it."""

    def __init__(self, cfg: ModelConfig, rl: RLConfig, task: ArithmeticTask,
                 algo="a3po", n_prompts: int = 16,
                 max_new_tokens: int = 8, queue_capacity: int = 4,
                 seed: int = 0, use_control_plane: bool = False,
                 serve_kwargs: Optional[Dict] = None,
                 decode_horizon: int = 8):
        self.cfg, self.rl, self.task = cfg, rl, task
        self.n_prompts = n_prompts
        self.max_new_tokens = max_new_tokens
        self.engine = RolloutEngine(cfg, rl, max_new_tokens)
        self.trainer = Trainer(cfg, rl, algo)
        self.algo = self.trainer.algo
        self.queue = RolloutQueue(queue_capacity, rl.max_staleness)
        self.seed = seed
        self._stop = threading.Event()
        self._rollout_times: List[float] = []
        # serving control plane (interruptible continuous batching with a
        # radix prefix cache) instead of the run-to-completion engine
        self.use_control_plane = use_control_plane
        # decode horizon for the continuous-batching engine: tokens per
        # compiled serving launch (host drains once per horizon). Weight
        # publishes are absorbed at horizon boundaries; per-token version
        # stamps stay truthful (first horizon token carries the version
        # that produced its logits).
        self.decode_horizon = decode_horizon
        self._serve_kwargs = serve_kwargs or {}
        self.control_plane = None

    def _build_control_plane(self, store: WeightStore):
        from repro.rollout.continuous import ContinuousBatchingEngine
        from repro.serving import (AdmissionScheduler, SchedulerConfig,
                                   ServingControlPlane)
        kw = dict(max_seqs=self.n_prompts * self.rl.group_size,
                  block_size=8, n_blocks=512, max_blocks_per_seq=16,
                  decode_horizon=self.decode_horizon)
        kw.update(self._serve_kwargs)
        srv = ContinuousBatchingEngine(self.cfg, rl=self.rl, **kw)
        return ServingControlPlane(
            srv, store,
            AdmissionScheduler(SchedulerConfig(d_max=self.rl.max_staleness)),
            rollout_queue=self.queue)

    def _rollout_once_cp(self, key):
        """Group rollout through the serving control plane: GRPO members
        share one prefill via the radix cache, and weight publishes landing
        mid-batch are absorbed with per-token version stamps."""
        batch = self.task.sample(self.n_prompts)
        group = self.rl.group_size
        prompts = np.repeat(batch.prompts, group, axis=0)
        lengths = np.repeat(batch.prompt_lengths, group)
        answers = [a for a in batch.answers for _ in range(group)]
        rb = self.control_plane.generate_batch(
            prompts, lengths, key, max_new=self.max_new_tokens)
        completions = self.engine.completions(rb)
        rewards = self.task.rewards(completions, answers)
        return rb, rewards

    def _rollout_worker(self, store: WeightStore):
        key = jax.random.PRNGKey(self.seed + 1)
        while not self._stop.is_set():
            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            if self.control_plane is not None:
                rb, rewards = self._rollout_once_cp(sub)
            else:
                params, version = store.latest()
                with span("rollout", version=version) as sp:
                    rb, rewards = _rollout_once(
                        self.engine, self.task, params, version,
                        self.n_prompts, self.rl.group_size, sub)
                    sp.set(reward_mean=float(np.mean(rewards)))
                    # close the publish->rollout flow arrow: first
                    # rollout generated under the published version
                    flow_end("publish", version)
            self._rollout_times.append(time.perf_counter() - t0)
            rb.rewards = rewards  # piggyback
            if not self.queue.push(rb, timeout=1.0):
                continue  # queue full — back-pressure

    def run(self, state: TrainState, num_steps: int,
            run_logger=None) -> (TrainState, List[StepRecord]):
        """Drive ``num_steps`` training steps against the live rollout
        worker. ``run_logger`` (``obs.runlog.RunLogger``) gets exactly one
        JSONL step record per training step."""
        store = WeightStore(state.params, int(state.version))
        if self.use_control_plane:
            self.control_plane = self._build_control_plane(store)
        worker = threading.Thread(target=self._rollout_worker,
                                  args=(store,), daemon=True,
                                  name="rollout-worker")
        t_start = time.perf_counter()
        worker.start()
        records: List[StepRecord] = []
        try:
            for step in range(num_steps):
                with step_annotation(step):
                    batches = self.queue.pop_fresh(int(state.version), n=1)
                    rewards = np.concatenate([b.rewards for b in batches])
                    tb = assemble_train_batch(batches, rewards)
                    t0 = time.perf_counter()
                    with span("train_step", step=step):
                        state, m = self.trainer.step(state, tb)
                    train_t = time.perf_counter() - t0
                    version = int(state.version)
                    with span("weight_publish", version=version):
                        store.publish(state.params, version)
                        # open the publish->resume flow arrow (closed by
                        # the first rollout/serving step under `version`)
                        flow_start("publish", version)
                serving = (self.control_plane.metrics.snapshot()
                           if self.control_plane is not None else None)
                records.append(StepRecord(
                    step=step, reward=m["reward_mean"], loss=m["loss"],
                    entropy=m.get("entropy", 0.0), iw_max=m["iw_max"],
                    iw_min=m["iw_min"], clipped_tokens=m["clipped_tokens"],
                    staleness_mean=m["staleness_mean"],
                    prox_time_s=m["prox_time_s"],
                    rollout_time_s=(np.mean(self._rollout_times[-3:])
                                    if self._rollout_times else 0.0),
                    train_time_s=train_t,
                    wall_time_s=time.perf_counter() - t_start,
                    serving=serving,
                    train_tokens=m.get("tokens", 0.0),
                    host_syncs=m.get("host_syncs", 0.0)))
                if run_logger is not None:
                    run_logger.log_step(records[-1])
        finally:
            self._stop.set()
            worker.join(timeout=10.0)
        return state, records


def simulate_async(cfg: ModelConfig, rl: RLConfig, task: ArithmeticTask,
                   algo, num_steps: int, *,
                   n_prompts: int = 8, max_new_tokens: int = 8,
                   staleness: int = 1, seed: int = 0,
                   init_state: Optional[TrainState] = None,
                   record_hook: Optional[Callable[[int, Dict], None]] = None,
                   eval_every: int = 0,
                   eval_fn: Optional[Callable] = None,
                   num_microbatches: int = 1,
                   run_logger=None,
                   ) -> (TrainState, List[StepRecord]):
    """Deterministic async simulation: behavior policy lags ``staleness``
    versions behind (0 == synchronous on-policy). ``algo`` is an
    ``Algorithm`` or registry name. ``eval_fn(params)`` is invoked every
    ``eval_every`` steps (the paper's held-out eval worker, Fig. 3);
    results land in ``StepRecord.eval_reward``. ``run_logger``
    (``obs.runlog.RunLogger``) gets one JSONL step record per step."""
    engine = RolloutEngine(cfg, rl, max_new_tokens)
    trainer = Trainer(cfg, rl, algo, num_microbatches=num_microbatches)
    key = jax.random.PRNGKey(seed)
    state = init_state or trainer.init_state(jax.random.PRNGKey(seed + 7))
    history: deque = deque(maxlen=staleness + 1)
    history.append((state.params, int(state.version)))
    records: List[StepRecord] = []
    t_start = time.perf_counter()
    for step in range(num_steps):
        behav_params, behav_version = history[0]
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        with span("rollout", step=step, version=behav_version) as sp:
            rb, rewards = _rollout_once(engine, task, behav_params,
                                        behav_version, n_prompts,
                                        rl.group_size, sub)
            sp.set(reward_mean=float(np.mean(rewards)))
            # close the publish->rollout staleness arrow: the simulated
            # behavior policy first acts `staleness` steps after publish
            flow_end("publish", behav_version)
        rollout_t = time.perf_counter() - t0
        tb = assemble_train_batch([rb], rewards)
        t0 = time.perf_counter()
        with step_annotation(step), span("train_step", step=step,
                                         staleness=staleness):
            state, m = trainer.step(state, tb)
        train_t = time.perf_counter() - t0
        version = int(state.version)
        with span("weight_publish", version=version):
            history.append((state.params, version))
            flow_start("publish", version)
        rec = StepRecord(
            step=step, reward=m["reward_mean"], loss=m["loss"],
            entropy=m.get("entropy", 0.0), iw_max=m["iw_max"],
            iw_min=m["iw_min"], clipped_tokens=m["clipped_tokens"],
            staleness_mean=m["staleness_mean"], prox_time_s=m["prox_time_s"],
            rollout_time_s=rollout_t, train_time_s=train_t,
            wall_time_s=time.perf_counter() - t_start,
            train_tokens=m.get("tokens", 0.0),
            host_syncs=m.get("host_syncs", 0.0))
        if eval_fn and eval_every and (step + 1) % eval_every == 0:
            rec.eval_reward = float(eval_fn(state.params))
        records.append(rec)
        if run_logger is not None:
            run_logger.log_step(rec)
        if record_hook:
            record_hook(step, m)
    return state, records
