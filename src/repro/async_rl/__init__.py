from repro.async_rl.buffer import RolloutQueue  # noqa: F401
from repro.async_rl.orchestrator import (  # noqa: F401
    AsyncOrchestrator,
    StepRecord,
    simulate_async,
)
from repro.async_rl.weights import WeightStore  # noqa: F401
