"""Virtual-clock trace replay over the serving control plane.

The harness is the "millions of users" instrument: it feeds a trace's
arrivals into ``ServingControlPlane.submit`` at their timestamps, drives
``step()`` to completion, and replays weight-publish events — all on a
**virtual clock**, so a run is a deterministic function of (trace, model,
policy): the clock advances by a fixed cost model per control-plane step
(overhead + per-prefill-chunk + per-decoded-token) instead of wall time,
and every request-lifecycle stamp (submit → admit → first token → done,
preempt/drop reasons) is in virtual seconds. Two runs of the same trace
produce byte-identical lifecycle JSONL.

Per-request lifecycle flows out three ways:

* ``obs.tracing`` spans: one ``request`` span per request (real wall
  clock, for Perfetto), inside a ``load_replay`` wrapper;
* per-class labeled ``serving_*`` histograms/counters in the
  ``obs.metrics`` registry (``serving_ttft_seconds{class="..."}``, ...);
* schema-versioned JSONL via ``obs.runlog`` (``kind="request"`` records
  + one ``kind="load_summary"`` with the per-class SLO table that
  ``repro.obs.report`` renders).

TTFT/E2E here are *virtual*: queueing + simulated service time. The
granularity is one control-plane step (the clock advances at step
boundaries), which cancels out in policy comparisons.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.async_rl.weights import WeightStore
from repro.loadgen.traces import (
    TRACE_SCHEMA_VERSION,
    Trace,
    TraceRequest,
    prompt_tokens,
)
from repro.loadgen.slo import SLOAwareScheduler, SLOPolicy
from repro.obs.metrics import get_registry
from repro.obs.runlog import RUNLOG_SCHEMA_VERSION, RunLogger
from repro.obs.tracing import span
from repro.rollout.continuous import ContinuousBatchingEngine, Request
from repro.serving import (
    AdmissionScheduler,
    SchedulerConfig,
    ServingControlPlane,
)

# virtual-seconds bucket ladders for the per-class labeled histograms
TTFT_BOUNDS = (0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 15.0)
E2E_BOUNDS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 120.0)

POLICIES = ("slo", "priority", "fifo")


class VirtualClock:
    """Deterministic replay clock; calling it is the control-plane clock
    protocol (``ServingControlPlane(clock=...)``)."""

    def __init__(self, t0: float = 0.0):
        self.now = t0

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        assert dt >= 0.0
        self.now += dt

    def advance_to(self, t: float) -> None:
        self.now = max(self.now, t)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Virtual cost of one control-plane step: fixed overhead plus what
    the step actually did. Defaults are in the ballpark of the committed
    toy-model CPU benches; absolute values only scale the virtual axis —
    policy comparisons are ratio-invariant."""

    step_overhead_s: float = 0.002
    prefill_chunk_s: float = 0.004
    decode_token_s: float = 0.0015

    def step_cost(self, chunks: int, tokens: int) -> float:
        return (self.step_overhead_s + self.prefill_chunk_s * chunks
                + self.decode_token_s * tokens)


@dataclasses.dataclass
class LoadResult:
    policy: str
    records: List[Dict[str, object]]     # one lifecycle dict per request
    summary: Dict[str, object]           # the kind="load_summary" record
    finished: List[Request]
    dropped: List[Request]
    steps: int
    virtual_time_s: float


def build_control_plane(cfg, params, trace: Trace, *, policy: str = "slo",
                        cost: Optional[CostModel] = None,
                        clock: Optional[VirtualClock] = None,
                        max_seqs: int = 4, block_size: int = 8,
                        decode_horizon: int = 4, prefill_chunk: int = 16,
                        prefill_budget: int = 2, d_max: int = 1_000_000,
                        age_promote_s: float = math.inf,
                        max_preempts: int = 4,
                        preempt_slack_frac: float = 0.25,
                        faults=None):
    """Engine + scheduler + control plane for a replay run.

    ``policy``: ``"slo"`` = priority classes + SLO shed/preempt;
    ``"priority"`` = priority classes only; ``"fifo"`` = single class in
    arrival order (the no-priority baseline).
    """
    assert policy in POLICIES, policy
    clock = clock or VirtualClock()
    cost = cost or CostModel()
    store = WeightStore(params, 0)
    longest = max((r.prompt_len + r.max_new for r in trace.requests),
                  default=block_size)
    mb = -(-longest // block_size) + 1
    engine = ContinuousBatchingEngine(
        cfg, max_seqs=max_seqs, block_size=block_size,
        n_blocks=max_seqs * mb + 1, max_blocks_per_seq=mb, greedy=True,
        decode_horizon=decode_horizon, prefill_chunk=prefill_chunk)
    sched_cfg = SchedulerConfig(d_max=d_max, max_preempts=max_preempts,
                                age_promote_s=age_promote_s)
    if policy == "slo":
        scheduler = SLOAwareScheduler(sched_cfg, SLOPolicy(
            classes=trace.classes,
            est_fixed_s=cost.step_overhead_s,
            est_s_per_token=cost.prefill_chunk_s / prefill_chunk,
            preempt_slack_frac=preempt_slack_frac))
    else:
        scheduler = AdmissionScheduler(sched_cfg)
    cp = ServingControlPlane(engine, store, scheduler,
                             use_prefix_cache=False,
                             resubmit_dropped=False,
                             prefill_budget=prefill_budget, clock=clock,
                             faults=faults)
    return cp, store, clock, cost


def _round(v: float, unset: float = -1.0) -> Optional[float]:
    return None if v == unset or v < 0 else round(v, 6)


class _ClassStats:
    """Per-class accumulator backed by labeled registry metrics."""

    def __init__(self, name: str, registry):
        labels = {"class": name}
        self.name = name
        self.ttft = registry.histogram("serving_ttft_seconds", TTFT_BOUNDS,
                                       **labels)
        self.e2e = registry.histogram("serving_e2e_seconds", E2E_BOUNDS,
                                      **labels)
        self.attained = registry.counter("serving_slo_attained_total",
                                         **labels)
        self.missed = registry.counter("serving_slo_missed_total", **labels)
        self.submitted = 0
        self.completed = 0
        self.dropped = 0
        self.shed = 0
        self.preempts = 0
        self.tokens = 0
        self.slo_tokens = 0

    def table_row(self, duration_s: float) -> Dict[str, object]:
        dur = max(duration_s, 1e-9)
        attained = int(self.attained.value)
        return {
            "submitted": self.submitted, "completed": self.completed,
            "dropped": self.dropped, "shed": self.shed,
            "preempts": self.preempts, "tokens": self.tokens,
            "ttft_p50_s": round(self.ttft.quantile(0.5), 6),
            "ttft_p99_s": round(self.ttft.quantile(0.99), 6),
            "ttft_mean_s": round(self.ttft.mean, 6),
            "e2e_p50_s": round(self.e2e.quantile(0.5), 6),
            "e2e_p99_s": round(self.e2e.quantile(0.99), 6),
            "slo_attained": attained,
            "slo_attainment": round(attained / max(self.submitted, 1), 6),
            "goodput_rps": round(attained / dur, 6),
            "goodput_tok_s": round(self.slo_tokens / dur, 6),
        }


def run_trace(cfg, params, trace: Trace, *, policy: str = "slo",
              logger: Optional[RunLogger] = None, seed: int = 0,
              max_steps: int = 500_000, **build_kw) -> LoadResult:
    """Replay ``trace`` through a fresh control plane; returns per-request
    lifecycle records + the per-class SLO summary."""
    cp, store, clock, cost = build_control_plane(
        cfg, params, trace, policy=policy, **build_kw)
    registry = get_registry()
    # fresh labeled per-class metrics for this run (the unlabeled
    # serving_* names stay owned by the ServingMetrics facade)
    for prefix in ("serving_ttft_seconds{", "serving_e2e_seconds{",
                   "serving_slo_attained_total{",
                   "serving_slo_missed_total{"):
        registry.unregister_prefix(prefix)
    stats = {c.name: _ClassStats(c.name, registry) for c in trace.classes}

    arrivals = deque(sorted(trace.requests,
                            key=lambda r: (r.t_arrival_s, r.rid)))
    publishes = deque(sorted(trace.publishes, key=lambda p: p.t_s))
    rid_to_trace: Dict[int, TraceRequest] = {}
    req_spans: Dict[int, object] = {}
    records: List[Dict[str, object]] = []
    key = jax.random.PRNGKey(seed)
    steps = 0

    def finalize(req: Request, outcome: str) -> None:
        tr = rid_to_trace[req.rid]
        cls = trace.class_by_name(tr.cls)
        st = stats[tr.cls]
        ttft = (req.t_first_token - req.t_submit
                if req.t_first_token >= 0 else -1.0)
        e2e = req.t_done - req.t_submit if req.t_done >= 0 else -1.0
        done = outcome == "done"
        ttft_ok = done and 0 <= ttft <= cls.ttft_slo_s
        e2e_ok = done and 0 <= e2e <= cls.e2e_slo_s
        if done:
            st.completed += 1
            st.tokens += len(req.generated)
            if ttft >= 0:
                st.ttft.observe(ttft)
            if e2e >= 0:
                st.e2e.observe(e2e)
        else:
            st.dropped += 1
            if req.drop_reason == "slo_shed":
                st.shed += 1
        st.preempts += req.preempt_count
        if ttft_ok and e2e_ok:
            st.attained.inc()
            st.slo_tokens += len(req.generated)
        else:
            st.missed.inc()
        rec = {
            "schema": RUNLOG_SCHEMA_VERSION, "kind": "request",
            "rid": tr.rid, "cls": tr.cls, "tenant": tr.tenant,
            "priority": tr.priority, "prompt_len": tr.prompt_len,
            "max_new": tr.max_new, "outcome": outcome,
            "drop_reason": req.drop_reason or None,
            "preempts": req.preempt_count,
            "tokens": len(req.generated),
            "t_arrival_s": tr.t_arrival_s,
            "t_submit_s": _round(req.t_submit),
            "t_admit_s": _round(req.t_admit),
            "t_first_token_s": _round(req.t_first_token),
            "t_done_s": _round(req.t_done),
            "ttft_s": _round(ttft), "e2e_s": _round(e2e),
            "slo_ttft_ok": ttft_ok, "slo_e2e_ok": e2e_ok,
        }
        records.append(rec)
        if logger is not None:
            # time_unix_s override keeps the JSONL deterministic: the
            # record is stamped with virtual completion time, not wall
            logger.log_event(**dict(rec, kind="request",
                                    time_unix_s=round(clock.now, 6)))
        s = req_spans.pop(req.rid, None)
        if s is not None:
            s.set(outcome=outcome, ttft_s=round(max(ttft, -1.0), 6),
                  preempts=req.preempt_count)
            s.__exit__(None, None, None)

    finished_reqs: List[Request] = []
    dropped_reqs: List[Request] = []
    with span("load_replay", policy=policy, requests=len(trace.requests)):
        while arrivals or cp.n_inflight or len(cp.scheduler):
            while publishes and publishes[0].t_s <= clock.now:
                ev = publishes.popleft()
                store.publish(params, ev.version)
            while arrivals and arrivals[0].t_arrival_s <= clock.now:
                tr = arrivals.popleft()
                prio = 0 if policy == "fifo" else tr.priority
                rid = cp.submit(prompt_tokens(tr, cfg.vocab_size),
                                max_new=tr.max_new, priority=prio,
                                tenant=tr.tenant)
                rid_to_trace[rid] = tr
                stats[tr.cls].submitted += 1
                s = span("request", rid=tr.rid, cls=tr.cls,
                         tenant=tr.tenant, priority=prio)
                s.__enter__()
                req_spans[rid] = s
            if cp.n_inflight or len(cp.scheduler):
                key, sub = jax.random.split(key)
                tok0 = cp.metrics.decode_tokens
                ch0 = cp.metrics.prefill_chunks
                finished = cp.step(sub)
                steps += 1
                clock.advance(cost.step_cost(
                    cp.metrics.prefill_chunks - ch0,
                    cp.metrics.decode_tokens - tok0))
                for r in finished:
                    finished_reqs.append(r)
                    finalize(r, "done")
                if cp.dropped_requests:
                    for r in cp.dropped_requests:
                        dropped_reqs.append(r)
                        finalize(r, "dropped")
                    cp.dropped_requests = []
                if steps > max_steps:
                    raise RuntimeError("load replay exceeded max_steps")
            elif arrivals:
                # idle: jump straight to the next arrival
                clock.advance_to(arrivals[0].t_arrival_s)

    duration = clock.now
    snap = cp.metrics.snapshot()
    summary = {
        "schema": RUNLOG_SCHEMA_VERSION, "kind": "load_summary",
        "trace_schema": TRACE_SCHEMA_VERSION, "policy": policy,
        "requests": len(trace.requests),
        "completed": len(finished_reqs), "dropped": len(dropped_reqs),
        "steps": steps, "virtual_time_s": round(duration, 6),
        "publishes": len(trace.publishes),
        "slo": {c.name: {"ttft_slo_s": c.ttft_slo_s,
                         "e2e_slo_s": c.e2e_slo_s}
                for c in trace.classes},
        "classes": {name: st.table_row(duration)
                    for name, st in stats.items()},
        # deterministic counter subset of the serving snapshot (wall-time
        # rates are deliberately excluded from the JSONL)
        "serving": {k: snap[k] for k in (
            "admitted", "completed", "drops", "drops_staleness_budget",
            "drops_max_preempts", "drops_slo_shed", "preemptions",
            "preemptions_staleness", "preemptions_slo", "interrupts",
            "resumed_sequences", "decode_tokens", "prefill_chunks")},
    }
    if logger is not None:
        logger.log_event(**dict(summary, kind="load_summary",
                                time_unix_s=round(duration, 6)))
    return LoadResult(policy=policy, records=records, summary=summary,
                      finished=finished_reqs, dropped=dropped_reqs,
                      steps=steps, virtual_time_s=duration)
