"""Fit virtual-clock ``CostModel`` coefficients from the real engine.

The load harness replays traces on a virtual clock whose step cost is a
frozen ``CostModel`` (overhead + per-prefill-chunk + per-decoded-token).
For single-arch policy comparisons the absolute coefficients cancel out,
but a *multi-architecture* replay table is only meaningful if each
architecture's clock reflects its actual step cost — an SSD decode step
and a paged-attention decode step are different machines.

``fit_cost_model`` measures a live ``ContinuousBatchingEngine``:

* ``prefill_chunk_s`` — warm median wall time of one full-width chunk
  launch (the first launch is discarded as the compile warmup);
* ``decode_token_s`` — marginal cost per decoded token, from the slope
  of warm horizon time across two horizon lengths at full occupancy
  (the engine's launches are fixed-shape over ``max_seqs``, so active
  slot count does not move wall time — scan length does);
* ``step_overhead_s`` — the short-horizon time minus its per-token
  share (the intercept).

Wall-clock fits are machine-specific by nature; committed benchmark
JSONs pin the coefficients fitted once on the dev machine (see
``benchmarks.bench_load``) so the replay itself stays deterministic.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import numpy as np

from repro.loadgen.harness import CostModel
from repro.rollout.continuous import ContinuousBatchingEngine, Request

_EPS_S = 1e-7  # floor: coefficients must stay positive for the replay


def _median(xs) -> float:
    return float(np.median(np.asarray(xs, np.float64)))


def _slot_of(engine: ContinuousBatchingEngine, rid: int) -> int:
    return next(s for s, r in engine.slots.items()
                if r is not None and r.rid == rid)


def fit_cost_model(cfg, params, *, max_seqs: int = 2,
                   decode_horizon: int = 4, prefill_chunk: int = 16,
                   block_size: int = 16, repeats: int = 3,
                   seed: int = 0) -> CostModel:
    """Measure one engine build's step costs; returns a ``CostModel``.

    Uses the same engine geometry the load harness builds so the fitted
    coefficients price the steps the replay actually counts. All timed
    launches are warm (compile discarded); each timing blocks on the
    launch's device outputs.
    """
    rng = np.random.RandomState(seed)
    key = jax.random.PRNGKey(seed)
    h_short, h_long = decode_horizon, 3 * decode_horizon

    def prompt(n: int) -> np.ndarray:
        return rng.randint(3, cfg.vocab_size, size=(n,)).astype(np.int32)

    def build(horizon: int) -> ContinuousBatchingEngine:
        mb = -(-(prefill_chunk + h_long * (repeats + 3)) // block_size) + 1
        return ContinuousBatchingEngine(
            cfg, max_seqs=max_seqs, block_size=block_size,
            n_blocks=max_seqs * mb + 1, max_blocks_per_seq=mb,
            greedy=True, decode_horizon=horizon,
            prefill_chunk=prefill_chunk)

    # --- prefill: one full-width chunk launch per timing ----------------
    # start_prefill (the control plane's streaming entry) only registers
    # the slot; the timed prefill_step owns the whole chunk launch.
    engine = build(h_short)
    prefill_times = []
    for it in range(repeats + 1):  # launch 0 pays the compile
        engine._rid += 1
        req = Request(engine._rid, prompt(prefill_chunk), 1)
        slot = engine.free_slots()[0]
        engine.start_prefill(slot, req)
        t0 = time.perf_counter()
        launched = engine.prefill_step(params, max_chunks=1)
        jax.block_until_ready(engine._next_logits)
        prefill_times.append(time.perf_counter() - t0)
        assert launched == 1 and not engine.prefilling_slots()
        engine.release_slot(slot)
    prefill_chunk_s = max(_median(prefill_times[1:]), _EPS_S)

    # --- decode: warm horizon time at full occupancy, two horizons ------
    def horizon_time(engine: ContinuousBatchingEngine) -> float:
        nonlocal key
        max_new = engine.decode_horizon * (repeats + 2)
        rids = [engine.submit(prompt(4), max_new=max_new)
                for _ in range(max_seqs)]
        engine._admit(params)
        while engine.prefilling_slots():
            engine.prefill_step(params)
        key, sub = jax.random.split(key)
        engine.step_horizon(params, sub)  # compile warmup
        times = []
        for _ in range(repeats):
            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            engine.step_horizon(params, sub)  # ends in a blocking drain
            times.append(time.perf_counter() - t0)
        for rid in rids:
            engine.release_slot(_slot_of(engine, rid))
        return _median(times)

    t_short = horizon_time(engine)
    t_long = horizon_time(build(h_long))

    decode_token_s = max(
        (t_long - t_short) / (max_seqs * (h_long - h_short)), _EPS_S)
    step_overhead_s = max(t_short - max_seqs * h_short * decode_token_s,
                          _EPS_S)
    return CostModel(step_overhead_s=round(step_overhead_s, 7),
                     prefill_chunk_s=round(prefill_chunk_s, 7),
                     decode_token_s=round(decode_token_s, 7))


def describe(cost: CostModel) -> str:
    return (f"overhead={cost.step_overhead_s * 1e3:.3f}ms "
            f"chunk={cost.prefill_chunk_s * 1e3:.3f}ms "
            f"token={cost.decode_token_s * 1e3:.3f}ms")
