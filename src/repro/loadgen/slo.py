"""Per-class SLO enforcement wired into the admission scheduler.

Two decisions turn SLO targets from passive measurement into scheduling
policy, both counted in ``ServingMetrics``:

* **deadline-aware admission (shedding)** — a queued request whose TTFT
  deadline can no longer be met even if admitted *right now* (estimated
  prefill time included) is shed with ``drop_reason="slo_shed"`` instead
  of burning slots on work the client already counts as failed. Shedding
  hopeless bulk work is what keeps the queue short enough for the
  classes that can still win;
* **overload preemption** — when no slot is free and the most urgent
  waiting request is about to violate its SLO (remaining slack below
  ``preempt_slack_frac`` of the class target), the lowest-priority
  in-flight slot is preempted (``preempt_reasons[slot]="slo_overload"``)
  and requeued under the normal ``max_preempts`` budget.

The scheduler subclasses ``AdmissionScheduler``: the staleness budget,
backpressure gates, and priority aging all still apply — SLO policy is
layered on top, not a replacement.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.loadgen.traces import SLOClass
from repro.rollout.continuous import Request
from repro.serving.scheduler import AdmissionScheduler, SchedulerConfig


@dataclasses.dataclass
class SLOPolicy:
    """Class table + the service-time model the shed/preempt decisions
    use. ``est_ttft_s`` is the optimistic time-to-first-token if a
    request were admitted immediately: fixed per-admission overhead plus
    a per-prompt-token prefill estimate (the harness derives both from
    its virtual cost model; production would fit them from history)."""

    classes: Tuple[SLOClass, ...]
    est_fixed_s: float = 0.0
    est_s_per_token: float = 0.0
    # preempt a lower class when the urgent head-of-queue's remaining
    # slack drops below this fraction of its TTFT target
    preempt_slack_frac: float = 0.25

    def __post_init__(self):
        self._by_prio: Dict[int, SLOClass] = {}
        for c in self.classes:
            self._by_prio.setdefault(c.priority, c)

    def by_priority(self, priority: int) -> Optional[SLOClass]:
        return self._by_prio.get(priority)

    def est_ttft_s(self, prompt_len: int) -> float:
        return self.est_fixed_s + self.est_s_per_token * prompt_len


class SLOAwareScheduler(AdmissionScheduler):
    """AdmissionScheduler + per-class TTFT deadlines.

    Requests are stamped with their class and absolute deadline at
    enqueue (``t_submit + ttft_slo_s``; a preempt-requeue keeps the
    original deadline — the client has been waiting since submit).
    """

    def __init__(self, config: Optional[SchedulerConfig] = None,
                 policy: Optional[SLOPolicy] = None):
        super().__init__(config)
        assert policy is not None, "SLOAwareScheduler needs an SLOPolicy"
        self.policy = policy
        self.sheds = 0
        self.slo_preempts = 0

    # ------------------------------------------------------------- enqueue
    def enqueue(self, req: Request, now_s: float = 0.0) -> None:
        cls = self.policy.by_priority(req.priority)
        if cls is not None:
            req.slo_class = cls.name
            if not math.isfinite(req.deadline_s):
                base = req.t_submit if req.t_submit >= 0.0 else now_s
                req.deadline_s = base + cls.ttft_slo_s
        super().enqueue(req, now_s)

    # ------------------------------------------------------------ shedding
    def _shed_hopeless(self, now_s: float) -> None:
        """Drop queued requests that cannot make their TTFT deadline even
        if admitted immediately."""
        keep: List[Tuple[int, int, float, Request]] = []
        shed = False
        for e in self._heap:
            req = e[3]
            if now_s + self.policy.est_ttft_s(len(req.prompt)) \
                    > req.deadline_s:
                req.drop_reason = "slo_shed"
                self.dropped.append(req)
                self.sheds += 1
                shed = True
            else:
                keep.append(e)
        if shed:
            heapq.heapify(keep)
            self._heap = keep

    def pop_admissible(self, now_version: int, *, engine,
                       queue_frac: float = 0.0, now_s: float = 0.0
                       ) -> Optional[Tuple[Request, float]]:
        self._shed_hopeless(now_s)
        return super().pop_admissible(now_version, engine=engine,
                                      queue_frac=queue_frac, now_s=now_s)

    # ---------------------------------------------------------- preemption
    def check_preempt(self, slots: Dict[int, Optional[Request]],
                      now_version: int, *, now_s: float = 0.0,
                      free_slots: int = 0) -> List[int]:
        out = super().check_preempt(slots, now_version, now_s=now_s,
                                    free_slots=free_slots)
        if free_slots > 0 or not self._heap:
            return out
        self._shed_hopeless(now_s)
        if not self._heap:
            return out
        prio, _, _, head = self._heap[0]
        cls = self.policy.by_priority(head.priority)
        if cls is None:
            return out
        slack = (head.deadline_s - now_s
                 - self.policy.est_ttft_s(len(head.prompt)))
        if slack > self.policy.preempt_slack_frac * cls.ttft_slo_s:
            return out
        # victim: the least-urgent in-flight request strictly below the
        # waiting class (ties broken toward the youngest grant)
        victims = [(r.priority, s) for s, r in slots.items()
                   if r is not None and r.priority > prio
                   and s not in self.preempt_reasons]
        if victims:
            slot = max(victims)[1]
            out.append(slot)
            self.preempt_reasons[slot] = "slo_overload"
            self.slo_preempts += 1
        return out
