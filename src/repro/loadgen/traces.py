"""Trace generation + the versioned trace schema for the load harness.

A *trace* is the full description of a multi-tenant workload: per-class
SLO targets, request arrivals (timestamp, tenant, class, prompt/output
lengths, prompt seed), and the trainer's weight-publish events. Traces
are either synthesized here — seeded, so the same config always yields
the same workload — or replayed from a JSONL file with the same schema,
so real serving traces can be captured once and replayed across PRs.

Everything in this module is numpy-only (no jax): the schema constants
are imported by ``repro.obs.validate`` without dragging in the engine.

JSONL schema (``TRACE_SCHEMA_VERSION`` rides in every record):

* one ``kind="trace_header"`` record — classes (with SLO targets) + the
  generator config that produced the trace;
* one ``kind="request"`` record per arrival, sorted by ``t_arrival_s``;
* ``kind="publish"`` records for weight-publish events.

Prompt *tokens* are not stored: each request carries a ``prompt_seed``
and the harness regenerates its tokens deterministically, keeping traces
small and model-vocabulary-agnostic.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

TRACE_SCHEMA_VERSION = 1

# Required keys for the harness's lifecycle JSONL output (one record per
# request outcome) and its run summary — the CI schema gate
# (repro.obs.validate --loadgen) keys off these.
LIFECYCLE_REQUIRED_KEYS = (
    "schema", "kind", "rid", "cls", "tenant", "priority", "outcome",
    "t_submit_s", "ttft_s", "e2e_s", "tokens", "preempts",
)
SUMMARY_REQUIRED_KEYS = (
    "schema", "kind", "policy", "requests", "completed", "dropped",
    "virtual_time_s", "classes",
)


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A priority class with its latency SLO targets.

    ``priority`` is the admission-scheduler class (lower = more urgent);
    ``share`` is this class's fraction of synthetic arrivals.
    """

    name: str
    priority: int
    ttft_slo_s: float   # time-to-first-token target (submit -> 1st token)
    e2e_slo_s: float    # end-to-end target (submit -> done)
    share: float = 0.0
    max_new: int = 8    # output-length cap for synthetic requests

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


# the default 3-class mix: latency-critical interactive traffic, standard
# API calls, and bulk/batch rollouts (the trainer's own GRPO groups)
DEFAULT_CLASSES: Tuple[SLOClass, ...] = (
    SLOClass("interactive", 0, ttft_slo_s=0.25, e2e_slo_s=1.50,
             share=0.25, max_new=8),
    SLOClass("standard", 1, ttft_slo_s=0.75, e2e_slo_s=4.00,
             share=0.45, max_new=12),
    SLOClass("bulk", 2, ttft_slo_s=3.00, e2e_slo_s=15.00,
             share=0.30, max_new=16),
)


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    rid: int
    t_arrival_s: float
    tenant: str
    cls: str
    priority: int
    prompt_len: int
    max_new: int
    prompt_seed: int


@dataclasses.dataclass(frozen=True)
class PublishEvent:
    t_s: float
    version: int


@dataclasses.dataclass
class Trace:
    classes: Tuple[SLOClass, ...]
    requests: List[TraceRequest]
    publishes: List[PublishEvent]
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def class_by_name(self, name: str) -> SLOClass:
        for c in self.classes:
            if c.name == name:
                return c
        raise KeyError(name)

    @property
    def duration_s(self) -> float:
        return float(self.meta.get("duration_s") or (
            self.requests[-1].t_arrival_s if self.requests else 0.0))


@dataclasses.dataclass
class TraceConfig:
    """Synthetic-workload knobs (all distributions seeded).

    Arrivals are a Gamma renewal process with mean rate ``rate_rps``:
    ``burstiness=1`` is Poisson; ``<1`` clumps arrivals into bursts with
    long gaps (heavier-than-exponential inter-arrival tail), which is
    what multi-tenant serving traffic looks like.
    """

    seed: int = 0
    duration_s: float = 6.0
    rate_rps: float = 10.0
    burstiness: float = 1.0        # gamma shape k (1 = Poisson)
    n_tenants: int = 4
    tenant_skew: float = 1.2       # zipf-ish tenant popularity exponent
    prompt_len_min: int = 8
    prompt_len_mean: int = 20
    prompt_len_max: int = 64
    prompt_len_sigma: float = 0.5  # lognormal spread
    publish_every_s: float = 0.0   # 0 = no weight publishes


def synthesize(cfg: TraceConfig,
               classes: Sequence[SLOClass] = DEFAULT_CLASSES) -> Trace:
    """Deterministic synthetic trace: same (cfg, classes) -> same trace."""
    assert cfg.burstiness > 0 and cfg.rate_rps > 0
    rng = np.random.default_rng(cfg.seed)
    shares = np.array([c.share for c in classes], np.float64)
    assert shares.sum() > 0, "classes need arrival shares"
    shares = shares / shares.sum()
    pop = 1.0 / np.arange(1, cfg.n_tenants + 1) ** cfg.tenant_skew
    pop = pop / pop.sum()

    k = cfg.burstiness
    requests: List[TraceRequest] = []
    t = 0.0
    rid = 0
    while True:
        t += float(rng.gamma(k, 1.0 / (k * cfg.rate_rps)))
        if t >= cfg.duration_s:
            break
        rid += 1
        c = classes[int(rng.choice(len(classes), p=shares))]
        tenant = f"tenant{int(rng.choice(cfg.n_tenants, p=pop))}"
        plen = int(np.clip(
            round(rng.lognormal(math.log(cfg.prompt_len_mean),
                                cfg.prompt_len_sigma)),
            cfg.prompt_len_min, cfg.prompt_len_max))
        max_new = int(rng.integers(max(1, c.max_new // 2), c.max_new + 1))
        requests.append(TraceRequest(
            rid=rid, t_arrival_s=round(t, 6), tenant=tenant, cls=c.name,
            priority=c.priority, prompt_len=plen, max_new=max_new,
            prompt_seed=int(rng.integers(0, 2 ** 31 - 1))))

    publishes: List[PublishEvent] = []
    if cfg.publish_every_s > 0:
        n_pubs = int(cfg.duration_s / cfg.publish_every_s)
        publishes = [PublishEvent(round((i + 1) * cfg.publish_every_s, 6),
                                  i + 1) for i in range(n_pubs)]
    return Trace(classes=tuple(classes), requests=requests,
                 publishes=publishes, meta=dataclasses.asdict(cfg))


def prompt_tokens(req: TraceRequest, vocab_size: int) -> np.ndarray:
    """Regenerate the request's prompt tokens from its seed (ids >= 4:
    the toy tokenizer reserves PAD/BOS/EOS/SEP)."""
    rng = np.random.default_rng(req.prompt_seed)
    return rng.integers(4, vocab_size, size=req.prompt_len).astype(np.int32)


# ------------------------------------------------------------------ JSONL io
def save_trace(path: str, trace: Trace) -> str:
    with open(path, "w") as f:
        json.dump({"schema": TRACE_SCHEMA_VERSION, "kind": "trace_header",
                   "classes": [c.to_dict() for c in trace.classes],
                   "meta": trace.meta}, f)
        f.write("\n")
        for r in trace.requests:
            rec = {"schema": TRACE_SCHEMA_VERSION, "kind": "request"}
            rec.update(dataclasses.asdict(r))
            json.dump(rec, f)
            f.write("\n")
        for p in trace.publishes:
            json.dump({"schema": TRACE_SCHEMA_VERSION, "kind": "publish",
                       "t_s": p.t_s, "version": p.version}, f)
            f.write("\n")
    return path


def load_trace(path: str) -> Trace:
    classes: Optional[Tuple[SLOClass, ...]] = None
    meta: Dict[str, object] = {}
    requests: List[TraceRequest] = []
    publishes: List[PublishEvent] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            schema = rec.get("schema")
            assert schema == TRACE_SCHEMA_VERSION, \
                f"trace schema {schema!r} != {TRACE_SCHEMA_VERSION}"
            kind = rec.get("kind")
            if kind == "trace_header":
                classes = tuple(SLOClass(**c) for c in rec["classes"])
                meta = rec.get("meta", {})
            elif kind == "request":
                requests.append(TraceRequest(**{
                    k: rec[k] for k in (
                        "rid", "t_arrival_s", "tenant", "cls", "priority",
                        "prompt_len", "max_new", "prompt_seed")}))
            elif kind == "publish":
                publishes.append(PublishEvent(rec["t_s"], rec["version"]))
    assert classes is not None, f"{path}: no trace_header record"
    requests.sort(key=lambda r: (r.t_arrival_s, r.rid))
    publishes.sort(key=lambda p: p.t_s)
    return Trace(classes=classes, requests=requests, publishes=publishes,
                 meta=meta)
