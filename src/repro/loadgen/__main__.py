"""Load-harness CLI: synthesize (or load) a trace and replay it.

    python -m repro.loadgen --trace synthetic --seed 0
    python -m repro.loadgen --trace path/to/trace.jsonl --policy fifo

Deterministic by construction: the same trace + seed + policy produces
byte-identical lifecycle JSONL (virtual-clock stamps only — validate
with two runs and ``cmp``). Output: per-request ``kind="request"``
records plus one ``kind="load_summary"`` (the per-class SLO table) in
``--jsonl``, rendered via ``repro.obs.report`` at the end of the run.
"""
from __future__ import annotations

import argparse
import math
import sys
import time
from typing import List, Optional


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.loadgen",
        description="Trace-driven multi-tenant load harness")
    p.add_argument("--trace", default="synthetic",
                   help="'synthetic' or a trace JSONL path")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--policy", default="slo",
                   choices=["slo", "priority", "fifo"])
    p.add_argument("--arch", default="toy-2m")
    p.add_argument("--duration", type=float, default=6.0,
                   help="synthetic trace length (virtual seconds)")
    p.add_argument("--rate", type=float, default=10.0,
                   help="synthetic mean arrival rate (requests/s)")
    p.add_argument("--burstiness", type=float, default=0.6,
                   help="gamma shape: 1=Poisson, <1 bursty")
    p.add_argument("--publish-every", type=float, default=2.0,
                   help="virtual seconds between weight publishes "
                        "(0 = none)")
    p.add_argument("--jsonl", default="loadgen_run.jsonl",
                   help="lifecycle JSONL output path")
    p.add_argument("--save-trace", default=None,
                   help="also write the (synthetic) trace JSONL here")
    p.add_argument("--max-seqs", type=int, default=4)
    p.add_argument("--horizon", type=int, default=4)
    p.add_argument("--prefill-chunk", type=int, default=16)
    p.add_argument("--d-max", type=int, default=1_000_000)
    p.add_argument("--age-promote-s", type=float, default=math.inf)
    p.add_argument("--fault", action="append", default=[],
                   metavar="KIND@AT[xN][:MAG]",
                   help="inject a deterministic serving fault "
                        "(repeatable): kv_exhaust@STEPxN:BLOCKS holds KV "
                        "blocks hostage, nan_logits@STEP poisons a decode "
                        "logits row")
    p.add_argument("--fault-seed", type=int, default=0)
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: short trace")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)

    # imports deferred so --help stays instant
    import dataclasses

    from repro.configs.registry import get_config
    from repro.loadgen.harness import run_trace
    from repro.loadgen.traces import (
        TraceConfig,
        load_trace,
        save_trace,
        synthesize,
    )
    from repro.models import model as M
    from repro.obs.report import render_load
    from repro.obs.runlog import RunLogger
    import jax

    if args.trace == "synthetic":
        duration = 2.0 if args.quick else args.duration
        rate = 6.0 if args.quick else args.rate
        trace = synthesize(TraceConfig(
            seed=args.seed, duration_s=duration, rate_rps=rate,
            burstiness=args.burstiness,
            publish_every_s=args.publish_every))
    else:
        trace = load_trace(args.trace)
    if args.save_trace:
        save_trace(args.save_trace, trace)

    cfg = dataclasses.replace(get_config(args.arch), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    with RunLogger(jsonl_path=args.jsonl, quiet=args.quiet) as logger:
        logger.log_event(
            "load_header", trace=args.trace, seed=args.seed,
            policy=args.policy, arch=args.arch,
            requests=len(trace.requests), classes=len(trace.classes),
            time_unix_s=0.0)  # fixed stamp: keep the file deterministic
        logger.print(f"replaying {len(trace.requests)} requests "
                     f"({len(trace.classes)} classes, "
                     f"{len(trace.publishes)} publishes) "
                     f"policy={args.policy} arch={args.arch}")
        t0 = time.perf_counter()
        faults = None
        if args.fault:
            from repro.resilience import FaultPlan
            faults = FaultPlan.from_strings(args.fault,
                                            seed=args.fault_seed)
        result = run_trace(
            cfg, params, trace, policy=args.policy, logger=logger,
            seed=args.seed, max_seqs=args.max_seqs,
            decode_horizon=args.horizon,
            prefill_chunk=args.prefill_chunk, d_max=args.d_max,
            age_promote_s=args.age_promote_s, faults=faults)
        wall = time.perf_counter() - t0
        logger.print(render_load(result.summary))
        logger.print(
            f"  wall {wall:.1f}s for {result.steps} control-plane steps "
            f"({result.virtual_time_s:.2f}s virtual)")
        logger.print(f"  lifecycle JSONL -> {args.jsonl}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
