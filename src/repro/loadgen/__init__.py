"""Trace-driven multi-tenant load harness over the serving control plane.

``traces`` synthesizes (or loads) versioned workload traces — bursty
arrivals, tenant mix, priority classes with SLO targets, weight-publish
events. ``harness`` replays them on a virtual clock through
``ServingControlPlane`` and records per-request lifecycles. ``slo``
turns the per-class SLO targets into scheduling policy (deadline-aware
shedding + overload preemption).

CLI: ``python -m repro.loadgen --trace synthetic --seed 0``.
"""
from repro.loadgen.traces import (
    DEFAULT_CLASSES,
    TRACE_SCHEMA_VERSION,
    PublishEvent,
    SLOClass,
    Trace,
    TraceConfig,
    TraceRequest,
    load_trace,
    prompt_tokens,
    save_trace,
    synthesize,
)

__all__ = [
    "DEFAULT_CLASSES",
    "PublishEvent",
    "SLOClass",
    "TRACE_SCHEMA_VERSION",
    "Trace",
    "TraceConfig",
    "TraceRequest",
    "load_trace",
    "prompt_tokens",
    "save_trace",
    "synthesize",
]
