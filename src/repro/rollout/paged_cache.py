"""Paged KV cache (vLLM-style) adapted to JAX/TPU.

The paper's rollout engines (SGLang/vLLM) rely on paged attention for
memory efficiency under continuous batching. GPU PagedAttention walks a
block table with pointer indirection inside the kernel; the TPU-native
adaptation keeps a *dense block pool* as one array and turns the block
table into a gather index — XLA lowers the page gather + attention to
contiguous DMA-friendly reads, and freed blocks are recycled by index
bookkeeping on the host.

Layout:
  pool_k/pool_v : [n_layers, n_blocks, block_size, KV, hd]
  block_tables  : [max_seqs, max_blocks_per_seq] int32 (-1 = unmapped)
  seq_lens      : [max_seqs] int32
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class PagedCacheState:
    pool_k: jax.Array
    pool_v: jax.Array
    block_tables: jax.Array  # [max_seqs, max_blocks]
    seq_lens: jax.Array      # [max_seqs]

    @property
    def block_size(self) -> int:
        return self.pool_k.shape[2]

    @property
    def max_blocks(self) -> int:
        return self.block_tables.shape[1]


class BlockAllocator:
    """Host-side free-list over pool blocks (shared across layers).

    Blocks are reference-counted so the radix prefix cache and multiple
    sequences can share one physical block (GRPO group members sharing a
    prefilled prompt). ``alloc`` hands out blocks at refcount 1;
    ``release`` decrements and only returns a block to the free list when
    its count reaches zero.
    """

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self.free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.refcount: Dict[int, int] = {}
        self.forks = 0  # copy-on-write forks performed (metrics)

    def alloc(self, n: int) -> List[int]:
        if len(self.free) < n:
            raise RuntimeError(f"paged cache OOM: need {n} blocks, "
                               f"have {len(self.free)}")
        blocks = [self.free.pop() for _ in range(n)]
        for b in blocks:
            self.refcount[b] = 1
        return blocks

    def incref(self, block: int) -> None:
        assert block in self.refcount, f"incref of unallocated block {block}"
        self.refcount[block] += 1

    def decref(self, block: int) -> bool:
        """Drop one reference; returns True when the block was freed."""
        rc = self.refcount.get(block)
        assert rc is not None and rc > 0, \
            f"decref of unallocated block {block}"
        if rc == 1:
            del self.refcount[block]
            self.free.append(block)
            return True
        self.refcount[block] = rc - 1
        return False

    def refs(self, block: int) -> int:
        return self.refcount.get(block, 0)

    def release(self, blocks: List[int]) -> None:
        for b in blocks:
            if b >= 0:
                self.decref(b)

    @property
    def n_free(self) -> int:
        return len(self.free)


def init_paged_cache(cfg: ModelConfig, *, n_blocks: int, block_size: int,
                     max_seqs: int, max_blocks_per_seq: int,
                     dtype=None) -> PagedCacheState:
    assert cfg.mla is None, \
        "paged cache supports GQA/MHA attention stacks (no MLA yet)"
    dtype = dtype or jnp.dtype(cfg.dtype)
    # attention-free (pure SSM) stacks get a zero-layer pool: block/length
    # bookkeeping stays uniform across architectures at zero memory cost.
    n_attn = sum(1 for k in cfg.block_kinds() if k == "attn")
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (n_attn, n_blocks, block_size, kv, hd)
    return PagedCacheState(
        pool_k=jnp.zeros(shape, dtype),
        pool_v=jnp.zeros(shape, dtype),
        block_tables=jnp.full((max_seqs, max_blocks_per_seq), -1, jnp.int32),
        seq_lens=jnp.zeros((max_seqs,), jnp.int32),
    )


# ------------------------------------------------------------ SSM state pool
@dataclasses.dataclass
class SSMStateCache:
    """Constant-size per-slot recurrent state for SSM/hybrid decode.

    Unlike KV, Mamba2 state does not grow with sequence length, so no
    block table is needed: engine slot ``i`` owns row ``i`` of each pool.

      conv  : [n_ssm_layers, max_seqs, d_conv-1, conv_dim]  (model dtype)
      state : [n_ssm_layers, max_seqs, nh, hd, d_state]     (float32)
    """
    conv: jax.Array
    state: jax.Array

    @property
    def max_seqs(self) -> int:
        return self.conv.shape[1]

    @property
    def n_layers(self) -> int:
        return self.conv.shape[0]


def init_ssm_state_cache(cfg: ModelConfig, *, max_seqs: int,
                         dtype=None) -> SSMStateCache:
    assert cfg.ssm is not None, "SSM state cache needs cfg.ssm"
    dtype = dtype or jnp.dtype(cfg.dtype)
    s, d = cfg.ssm, cfg.d_model
    n_ssm = sum(1 for k in cfg.block_kinds() if k == "ssm")
    conv_dim = s.d_inner(d) + 2 * s.d_state
    return SSMStateCache(
        conv=jnp.zeros((n_ssm, max_seqs, s.d_conv - 1, conv_dim), dtype),
        state=jnp.zeros((n_ssm, max_seqs, s.num_heads(d), s.head_dim,
                         s.d_state), jnp.float32),
    )


def ssm_reset_slots(cache: SSMStateCache, slots) -> SSMStateCache:
    """Zero conv window + state for ``slots`` (fresh sequences)."""
    slots = jnp.asarray(slots, jnp.int32)
    return SSMStateCache(conv=cache.conv.at[:, slots].set(0),
                         state=cache.state.at[:, slots].set(0.0))


def ssm_fork_slot(cache: SSMStateCache, src: int, dst: int) -> SSMStateCache:
    """Clone slot ``src``'s recurrent state into ``dst``.

    The SSM analogue of ``fork_block``: recurrent state is private per
    slot (nothing is refcounted), so a fork is a plain copy.
    """
    return SSMStateCache(conv=cache.conv.at[:, dst].set(cache.conv[:, src]),
                         state=cache.state.at[:, dst].set(
                             cache.state[:, src]))


class SSMSlotPool:
    """Host-side lifecycle mirror for SSM-state slots.

    Constant-size state needs no free-list — slot ids are the engine's
    own — but the *lifecycle* must mirror ``BlockAllocator``'s: map on
    admit, release on finish/preempt (a released slot is re-zeroed before
    reuse), fork when a mapped slot's state is cloned. The pool tracks
    the mapped set and turns double-map / double-release bookkeeping bugs
    into immediate assertions, the way the KV path surfaces them as
    refcount errors.
    """

    def __init__(self, max_seqs: int):
        self.max_seqs = max_seqs
        self.mapped: set = set()
        self.forks = 0  # state clones performed (metrics)

    def map(self, slot: int) -> None:
        assert 0 <= slot < self.max_seqs, f"SSM slot {slot} out of range"
        assert slot not in self.mapped, f"double map of SSM slot {slot}"
        self.mapped.add(slot)

    def release(self, slot: int) -> None:
        assert slot in self.mapped, f"release of unmapped SSM slot {slot}"
        self.mapped.discard(slot)

    def fork(self, src: int, dst: int) -> None:
        assert src in self.mapped, f"fork from unmapped SSM slot {src}"
        self.map(dst)
        self.forks += 1

    def is_mapped(self, slot: int) -> bool:
        return slot in self.mapped

    @property
    def n_free(self) -> int:
        return self.max_seqs - len(self.mapped)


# ------------------------------------------------------------------ device ops
def write_token(state: PagedCacheState, layer: int, k: jax.Array,
                v: jax.Array, slot_ids: jax.Array) -> PagedCacheState:
    """Write one token's K/V for active slots.

    k, v: [B_active, KV, hd]; slot_ids: [B_active] rows of block_tables.
    The target block/offset come from seq_lens (position = current len).
    """
    bs = state.block_size
    lens = state.seq_lens[slot_ids]
    block_idx = lens // bs
    offset = lens % bs
    blocks = state.block_tables[slot_ids, block_idx]  # [B_active]
    # Unmapped (-1) positions are routed to the scratch block — the last
    # pool block, which the engine reserves as a write sink (the prefill
    # lane uses the same convention) — never to live block 0: a
    # bookkeeping bug then wastes a write instead of corrupting KV.
    unmapped = blocks < 0
    blocks = jnp.where(unmapped, state.pool_k.shape[1] - 1, blocks)
    offset = jnp.where(unmapped, 0, offset)

    pool_k = state.pool_k.at[layer, blocks, offset].set(
        k.astype(state.pool_k.dtype))
    pool_v = state.pool_v.at[layer, blocks, offset].set(
        v.astype(state.pool_v.dtype))
    return dataclasses.replace(state, pool_k=pool_k, pool_v=pool_v)


def gather_kv(state: PagedCacheState, layer: int, slot_ids: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Materialize per-slot K/V views [B, max_blocks*bs, KV, hd] + validity.

    This is the TPU adaptation of the paged-attention pointer walk: a
    gather over the block pool (one XLA gather per layer), letting the
    regular decode attention run on the result.
    """
    bs = state.block_size
    tables = state.block_tables[slot_ids]            # [B, max_blocks]
    safe = jnp.maximum(tables, 0)
    k = state.pool_k[layer][safe]                    # [B, mb, bs, KV, hd]
    v = state.pool_v[layer][safe]
    B, mb = tables.shape
    k = k.reshape(B, mb * bs, *k.shape[3:])
    v = v.reshape(B, mb * bs, *v.shape[3:])
    lens = state.seq_lens[slot_ids]
    valid = jnp.arange(mb * bs)[None, :] < lens[:, None]
    # tokens in unmapped blocks are never valid (len bound covers them)
    return k, v, valid


def bump_lens(state: PagedCacheState, slot_ids: jax.Array
              ) -> PagedCacheState:
    return dataclasses.replace(
        state, seq_lens=state.seq_lens.at[slot_ids].add(1))


# ------------------------------------------------------------------- host ops
def map_sequence(state: PagedCacheState, allocator: BlockAllocator,
                 slot: int, n_tokens: int) -> PagedCacheState:
    """Allocate blocks for a new sequence of n_tokens (prefill) + growth."""
    bs = state.block_size
    n_needed = -(-n_tokens // bs)
    blocks = allocator.alloc(n_needed)
    table = np.asarray(state.block_tables[slot]).copy()
    table[:] = -1
    table[: n_needed] = blocks
    return dataclasses.replace(
        state,
        block_tables=state.block_tables.at[slot].set(jnp.asarray(table)),
        seq_lens=state.seq_lens.at[slot].set(0),
    )


def ensure_capacity(state: PagedCacheState, allocator: BlockAllocator,
                    slot: int) -> PagedCacheState:
    """Grow the sequence's table by one block if the next token needs it."""
    bs = state.block_size
    length = int(state.seq_lens[slot])
    block_idx = length // bs
    if block_idx >= state.max_blocks:
        raise RuntimeError("sequence exceeded max_blocks_per_seq")
    if int(state.block_tables[slot, block_idx]) < 0:
        (blk,) = allocator.alloc(1)
        state = dataclasses.replace(
            state, block_tables=state.block_tables.at[slot, block_idx].set(
                blk))
    return state


def write_range(length: int, n_tokens: int, block_size: int,
                max_blocks: int) -> Tuple[int, int]:
    """(first, last) block indices the next ``n_tokens`` writes of a
    sequence at ``length`` will touch — the single definition both the
    headroom estimate and the actual allocation use."""
    first = length // block_size
    last = (length + n_tokens - 1) // block_size
    if last >= max_blocks:
        raise RuntimeError("sequence exceeded max_blocks_per_seq")
    return first, last


def alloc_horizon_blocks(allocator: BlockAllocator, tables: np.ndarray,
                         lens: np.ndarray, slot_tokens: Dict[int, int],
                         block_size: int) -> bool:
    """Pre-map every block the next ``n`` writes of each slot will touch.

    ``slot_tokens`` maps slot -> upcoming token count (a decode horizon).
    ``tables``/``lens`` are the caller's *host mirrors* of the device
    block tables and sequence lengths: the mirror is edited in place and
    no device readback happens here, so a fused multi-token decode can be
    prepared with zero blocking transfers (the caller pushes the mirror
    to the device once, if anything changed). Returns True when at least
    one block was mapped.
    """
    changed = False
    for slot, n_tokens in slot_tokens.items():
        if n_tokens <= 0:
            continue
        first, last = write_range(int(lens[slot]), n_tokens, block_size,
                                  tables.shape[1])
        for i in range(first, last + 1):
            if tables[slot, i] < 0:
                (blk,) = allocator.alloc(1)
                tables[slot, i] = blk
                changed = True
    return changed


def map_sequence_prefixed(state: PagedCacheState, allocator: BlockAllocator,
                          slot: int, prefix_blocks: List[int],
                          n_prefix_tokens: int, n_tokens: int
                          ) -> PagedCacheState:
    """Map a sequence whose first ``n_prefix_tokens`` live in shared blocks.

    ``prefix_blocks`` must already carry a reference for this sequence
    (the prefix cache increfs on match); only the remainder of the table
    is freshly allocated. ``seq_lens`` starts at ``n_prefix_tokens`` —
    the cached KV is already resident, so prefill only has to run the
    suffix.
    """
    bs = state.block_size
    n_needed = -(-n_tokens // bs)
    assert n_needed <= state.max_blocks, "sequence exceeds max_blocks_per_seq"
    assert len(prefix_blocks) <= n_needed, (prefix_blocks, n_tokens)
    fresh = allocator.alloc(n_needed - len(prefix_blocks))
    table = np.full((state.max_blocks,), -1, np.int32)
    table[: len(prefix_blocks)] = prefix_blocks
    table[len(prefix_blocks): n_needed] = fresh
    return dataclasses.replace(
        state,
        block_tables=state.block_tables.at[slot].set(jnp.asarray(table)),
        seq_lens=state.seq_lens.at[slot].set(n_prefix_tokens),
    )


def fork_block(state: PagedCacheState, allocator: BlockAllocator,
               block: int) -> Tuple[PagedCacheState, int]:
    """Copy-on-write: clone ``block`` into a fresh private block.

    Copies the pool contents across all layers and drops one reference on
    the shared original.
    """
    (new,) = allocator.alloc(1)
    pool_k = state.pool_k.at[:, new].set(state.pool_k[:, block])
    pool_v = state.pool_v.at[:, new].set(state.pool_v[:, block])
    allocator.decref(block)
    allocator.forks += 1
    return dataclasses.replace(state, pool_k=pool_k, pool_v=pool_v), new


def ensure_writable(state: PagedCacheState, allocator: BlockAllocator,
                    slot: int) -> PagedCacheState:
    """CoW guard: fork the block the next token writes into if shared.

    A slot resuming on top of radix-cached prompt blocks may have its
    write position inside a block other sequences (or the cache itself)
    still reference; writing there would corrupt the shared prefix.
    """
    bs = state.block_size
    length = int(state.seq_lens[slot])
    block_idx = length // bs
    if block_idx >= state.max_blocks:
        return state  # ensure_capacity raises the real error
    blk = int(state.block_tables[slot, block_idx])
    if blk >= 0 and allocator.refs(blk) > 1:
        state, new = fork_block(state, allocator, blk)
        state = dataclasses.replace(
            state, block_tables=state.block_tables.at[slot, block_idx].set(
                new))
    return state


def release_sequence(state: PagedCacheState, allocator: BlockAllocator,
                     slot: int) -> PagedCacheState:
    table = [int(b) for b in np.asarray(state.block_tables[slot])]
    allocator.release([b for b in table if b >= 0])
    return dataclasses.replace(
        state,
        block_tables=state.block_tables.at[slot].set(
            jnp.full((state.max_blocks,), -1, jnp.int32)),
        seq_lens=state.seq_lens.at[slot].set(0),
    )
