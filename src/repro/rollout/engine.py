"""Batched autoregressive rollout engine (the in-framework SGLang/vLLM).

``generate`` is a single jit'd program: prefill the (right-padded, ragged)
prompts, then a ``lax.scan`` over decode steps with sampling. It returns
the sequences, per-token behavior log-probs, and the response mask — plus
the policy version tag the async runtime stamps on every batch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.data import tokenizer as tok
from repro.models import model as M
from repro.models.layers import logits_from_hidden
from repro.obs.tracing import annotate, span
from repro.rollout.sampler import fused_sample_step


@dataclasses.dataclass
class RolloutBatch:
    """One generation batch (host-side, numpy)."""

    tokens: np.ndarray         # [B, P + N] prompts + generations (PAD after EOS)
    prompt_lengths: np.ndarray  # [B]
    gen_logp: np.ndarray       # [B, N] behavior logp of generated tokens
    gen_mask: np.ndarray       # [B, N] 1.0 up to & including EOS
    version: int = 0           # behavior policy version (stamped by caller)
    rewards: Optional[np.ndarray] = None  # [B] attached after verification
    # [B, N] per-token weight versions when generation crossed a publish
    # (interruptible serving); None => every token was sampled at `version`
    gen_versions: Optional[np.ndarray] = None

    @property
    def batch_size(self) -> int:
        return self.tokens.shape[0]

    def min_version(self) -> int:
        """Oldest behavior version in the batch (staleness gate input)."""
        if self.gen_versions is None:
            return self.version
        stamped = self.gen_versions[self.gen_mask > 0]
        return int(stamped.min()) if stamped.size else self.version


@functools.partial(jax.jit, static_argnames=("cfg", "max_new", "temperature",
                                             "top_p", "greedy"))
def _generate_jit(params, cfg: ModelConfig, prompts, prompt_lengths, key,
                  max_new: int, temperature: float, top_p: float,
                  greedy: bool = False):
    B, P = prompts.shape
    hidden, cache = M.prefill(params, cfg, prompts, lengths=prompt_lengths,
                              max_len=P + max_new)
    last_h = jnp.take_along_axis(
        hidden, (prompt_lengths - 1)[:, None, None].astype(jnp.int32),
        axis=1)[:, 0]
    logits = logits_from_hidden(params["embedding"], last_h, cfg)

    def step(carry, key_t):
        logits, cache, done = carry
        token, logp, mask, done = fused_sample_step(
            logits, key_t, done, temperature=temperature, top_p=top_p,
            greedy=greedy)
        logits, cache = M.decode_step(params, cfg, cache, token)
        return (logits, cache, done), (token, logp, mask)

    keys = jax.random.split(key, max_new)
    done0 = jnp.zeros((B,), bool)
    (_, _, _), (tokens, logps, masks) = jax.lax.scan(
        step, (logits, cache, done0), keys)
    return tokens.T, logps.T, masks.T  # [B, N]


class RolloutEngine:
    """Holds generation settings; weights are passed per call (the async
    runtime swaps them under us, exactly like an inference engine receiving
    weight updates)."""

    def __init__(self, cfg: ModelConfig, rl: Optional[RLConfig] = None,
                 max_new_tokens: int = 16):
        self.cfg = cfg
        self.rl = rl or RLConfig()
        self.max_new_tokens = max_new_tokens

    def generate(self, params, prompts: np.ndarray,
                 prompt_lengths: np.ndarray, key, *, version: int = 0,
                 greedy: bool = False) -> RolloutBatch:
        with span("rollout_generate", batch=int(prompts.shape[0]),
                  max_new=self.max_new_tokens, version=version), \
                annotate("rollout_generate"):
            toks, logps, masks = _generate_jit(
                params, self.cfg, jnp.asarray(prompts),
                jnp.asarray(prompt_lengths), key, self.max_new_tokens,
                self.rl.temperature, self.rl.top_p, greedy)
            toks = np.asarray(toks)
        B, P = prompts.shape
        full = np.concatenate([prompts, np.full_like(toks, tok.PAD)], axis=1)
        # place generated tokens right after each ragged prompt
        for b in range(B):
            L = int(prompt_lengths[b])
            full[b, L: L + toks.shape[1]] = toks[b]
        return RolloutBatch(
            tokens=full,
            prompt_lengths=np.asarray(prompt_lengths),
            gen_logp=np.asarray(logps),
            gen_mask=np.asarray(masks),
            version=version,
        )

    def completions(self, batch: RolloutBatch) -> list:
        """Decode generated token ids (up to EOS) per sequence."""
        out = []
        N = batch.gen_logp.shape[1]
        for b in range(batch.batch_size):
            L = int(batch.prompt_lengths[b])
            out.append(batch.tokens[b, L: L + N])
        return out
