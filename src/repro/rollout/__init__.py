from repro.rollout.engine import RolloutBatch, RolloutEngine  # noqa: F401
from repro.rollout.sampler import greedy_token, sample_token  # noqa: F401
