"""Token sampling: temperature + top-p, returning behavior log-probs.

The behavior log-prob is recorded under the *tempered* distribution (the
actual sampling policy). With the paper's settings (temperature=1.0,
top_p=1.0) this equals the model distribution, matching what SGLang/vLLM
report to AReaL.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.data import tokenizer as tok


def sample_token(logits: jax.Array, key: jax.Array, *,
                 temperature: float = 1.0, top_p: float = 1.0
                 ) -> Tuple[jax.Array, jax.Array]:
    """logits [B, V] -> (token [B], behav_logp [B])."""
    logits = logits.astype(jnp.float32) / max(temperature, 1e-6)
    logp_full = jax.nn.log_softmax(logits, axis=-1)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass >= top_p
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None],
                                     axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    token = jax.random.categorical(key, logits, axis=-1)
    behav_logp = jnp.take_along_axis(logp_full, token[:, None], axis=-1)[:, 0]
    return token, behav_logp


def greedy_token(logits: jax.Array) -> Tuple[jax.Array, jax.Array]:
    logp_full = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    token = jnp.argmax(logits, axis=-1)
    return token, jnp.take_along_axis(logp_full, token[:, None],
                                      axis=-1)[:, 0]


def fused_sample_step(logits: jax.Array, key: jax.Array, done: jax.Array, *,
                      temperature: float = 1.0, top_p: float = 1.0,
                      greedy: bool = False
                      ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One on-device step of a fused (scanned) decode loop.

    Samples a token per row, masks rows that already finished (PAD token,
    zero logp, zero mask) and folds the EOS check into the done flags —
    the shared sampling step of ``RolloutEngine._generate_jit`` and the
    continuous-batching engine's fused decode horizon.

    logits [B,V]; done [B] bool -> (token [B], logp [B], mask [B] f32,
    done' [B]). ``mask`` is 1.0 exactly where a token was emitted (up to
    and including EOS); ``greedy`` ignores ``key``.
    """
    if greedy:
        token, logp = greedy_token(logits)
    else:
        token, logp = sample_token(logits, key, temperature=temperature,
                                   top_p=top_p)
    token = jnp.where(done, tok.PAD, token)
    logp = jnp.where(done, 0.0, logp)
    mask = (~done).astype(jnp.float32)
    done = done | (token == tok.EOS)
    return token, logp, mask, done
