"""Token sampling: temperature + top-p, returning behavior log-probs.

The behavior log-prob is recorded under the *tempered* distribution (the
actual sampling policy). With the paper's settings (temperature=1.0,
top_p=1.0) this equals the model distribution, matching what SGLang/vLLM
report to AReaL.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sample_token(logits: jax.Array, key: jax.Array, *,
                 temperature: float = 1.0, top_p: float = 1.0
                 ) -> Tuple[jax.Array, jax.Array]:
    """logits [B, V] -> (token [B], behav_logp [B])."""
    logits = logits.astype(jnp.float32) / max(temperature, 1e-6)
    logp_full = jax.nn.log_softmax(logits, axis=-1)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass >= top_p
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None],
                                     axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    token = jax.random.categorical(key, logits, axis=-1)
    behav_logp = jnp.take_along_axis(logp_full, token[:, None], axis=-1)[:, 0]
    return token, behav_logp


def greedy_token(logits: jax.Array) -> Tuple[jax.Array, jax.Array]:
    logp_full = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    token = jnp.argmax(logits, axis=-1)
    return token, jnp.take_along_axis(logp_full, token[:, None],
                                      axis=-1)[:, 0]
