"""Continuous batching server over the paged KV cache.

Requests are admitted into fixed slots as others finish (so the decode
step compiles once for ``max_seqs``); finished sequences release their
pages back to the allocator. This is the serving loop the paper's rollout
engines (vLLM/SGLang) implement, in-framework.

Supports dense GQA/MHA architectures (the paged pool holds per-layer K/V).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.data import tokenizer as tok
from repro.models import model as M
from repro.models.attention import decode_attention
from repro.models.layers import (
    apply_rope,
    embed_tokens,
    logits_from_hidden,
    rmsnorm,
)
from repro.models.layers import swiglu
from repro.rollout import paged_cache as pc
from repro.rollout.sampler import greedy_token, sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [P] token ids (unpadded)
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@functools.partial(jax.jit, static_argnames=("cfg",))
def _paged_decode_step(params, cfg: ModelConfig, pool_k, pool_v,
                       block_tables, seq_lens, tokens):
    """One token for every slot against the paged pool.

    tokens: [S_max]; returns (logits [S_max, V], pool_k, pool_v).
    """
    bs = pool_k.shape[2]
    n_slots, max_blocks = block_tables.shape
    x = embed_tokens(params["embedding"], tokens[:, None], cfg)[:, 0]
    lens = seq_lens
    safe_tables = jnp.maximum(block_tables, 0)

    blk_idx = lens // bs
    offset = lens % bs
    write_block = jnp.take_along_axis(safe_tables, blk_idx[:, None],
                                      axis=1)[:, 0]

    def layer(carry, xs):
        x, pool_k, pool_v = carry
        lp, li = xs
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        ap = lp["attn"]
        q = jnp.einsum("bd,dhk->bhk", h, ap["wq"])
        k = jnp.einsum("bd,dhk->bhk", h, ap["wk"])
        v = jnp.einsum("bd,dhk->bhk", h, ap["wv"])
        if cfg.qkv_bias:
            q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
        q = apply_rope(q[:, None], lens[:, None], cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], lens[:, None], cfg.rope_theta)[:, 0]

        pool_k = pool_k.at[li, write_block, offset].set(
            k.astype(pool_k.dtype))
        pool_v = pool_v.at[li, write_block, offset].set(
            v.astype(pool_v.dtype))

        kv_k = pool_k[li][safe_tables].reshape(
            n_slots, max_blocks * bs, *pool_k.shape[3:])
        kv_v = pool_v[li][safe_tables].reshape(
            n_slots, max_blocks * bs, *pool_v.shape[3:])
        valid = jnp.arange(max_blocks * bs)[None, :] <= lens[:, None]
        o = decode_attention(q, kv_k, kv_v, valid)
        y = jnp.einsum("bhk,hkd->bd", o, ap["wo"])
        if cfg.parallel_block:
            f = swiglu(lp["ffn"], h)
            x = x + y + f
        else:
            x = x + y
            h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            x = x + swiglu(lp["ffn"], h2)
        return (x, pool_k, pool_v), None

    li = jnp.arange(len(cfg.block_kinds()), dtype=jnp.int32)
    (x, pool_k, pool_v), _ = jax.lax.scan(
        layer, (x, pool_k, pool_v), (params["blocks"], li))
    x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)[:, 0]
    logits = logits_from_hidden(params["embedding"], x, cfg)
    return logits, pool_k, pool_v


class ContinuousBatchingEngine:
    def __init__(self, cfg: ModelConfig, *, max_seqs: int = 8,
                 block_size: int = 16, n_blocks: int = 256,
                 max_blocks_per_seq: int = 16,
                 rl: Optional[RLConfig] = None, greedy: bool = False):
        assert cfg.arch_type in ("dense",), "paged serving: dense archs"
        self.cfg = cfg
        self.rl = rl or RLConfig()
        self.greedy = greedy
        self.max_seqs = max_seqs
        # reserve the last block as the scratch target for idle slots
        self.allocator = pc.BlockAllocator(n_blocks - 1)
        self.trash_block = n_blocks - 1
        self.state = pc.init_paged_cache(
            cfg, n_blocks=n_blocks, block_size=block_size,
            max_seqs=max_seqs, max_blocks_per_seq=max_blocks_per_seq,
            dtype=jnp.dtype(cfg.dtype))
        # idle slots write into the scratch block
        bt = np.full((max_seqs, max_blocks_per_seq), -1, np.int32)
        bt[:, 0] = self.trash_block
        self.state = dataclasses.replace(
            self.state, block_tables=jnp.asarray(bt))
        self.slots: Dict[int, Optional[Request]] = {
            i: None for i in range(max_seqs)}
        self._pending: List[Request] = []
        self._next_logits = jnp.zeros((max_seqs, cfg.vocab_size),
                                      jnp.float32)
        self._rid = 0

    # ------------------------------------------------------------- requests
    def submit(self, prompt_ids, max_new: int = 16) -> int:
        self._rid += 1
        self._pending.append(Request(self._rid, np.asarray(prompt_ids),
                                     max_new))
        return self._rid

    def _admit(self, params) -> None:
        for slot, req in self.slots.items():
            if req is not None or not self._pending:
                continue
            nxt = self._pending[0]
            blocks_needed = -(-(len(nxt.prompt) + nxt.max_new)
                              // self.state.block_size)
            if blocks_needed > self.allocator.n_free:
                break
            self._pending.pop(0)
            self.slots[slot] = nxt
            self._prefill_into(params, slot, nxt)

    def _prefill_into(self, params, slot: int, req: Request) -> None:
        P = len(req.prompt)
        self.state = pc.map_sequence(self.state, self.allocator, slot,
                                     P + req.max_new)
        toks = jnp.asarray(req.prompt)[None, :]
        hidden, cache = M.prefill(params, self.cfg, toks, max_len=P)
        # copy dense prefill K/V into this sequence's pages
        bs = self.state.block_size
        table = np.asarray(self.state.block_tables[slot])
        k = cache["attn"]["k"][:, 0]  # [L, P, KV, hd]
        v = cache["attn"]["v"][:, 0]
        pool_k, pool_v = self.state.pool_k, self.state.pool_v
        for start in range(0, P, bs):
            blk = int(table[start // bs])
            n = min(bs, P - start)
            pool_k = pool_k.at[:, blk, :n].set(k[:, start:start + n])
            pool_v = pool_v.at[:, blk, :n].set(v[:, start:start + n])
        self.state = dataclasses.replace(
            self.state, pool_k=pool_k, pool_v=pool_v,
            seq_lens=self.state.seq_lens.at[slot].set(P))
        logits = logits_from_hidden(params["embedding"], hidden[:, -1],
                                    self.cfg)
        self._next_logits = self._next_logits.at[slot].set(logits[0])

    # ----------------------------------------------------------------- step
    def step(self, params, key) -> List[Request]:
        """One decode step for every active slot; returns finished reqs."""
        if self.greedy:
            tokens, _ = greedy_token(self._next_logits)
        else:
            tokens, _ = sample_token(self._next_logits, key,
                                     temperature=self.rl.temperature,
                                     top_p=self.rl.top_p)
        tokens = np.asarray(tokens)
        active = [s for s, r in self.slots.items() if r is not None]
        for slot in active:
            self.state = pc.ensure_capacity(self.state, self.allocator,
                                            slot)
        logits, pool_k, pool_v = _paged_decode_step(
            params, self.cfg, self.state.pool_k, self.state.pool_v,
            self.state.block_tables, self.state.seq_lens,
            jnp.asarray(tokens))
        self._next_logits = logits
        # bump active lens only
        lens = self.state.seq_lens
        for slot in active:
            lens = lens.at[slot].add(1)
        self.state = dataclasses.replace(self.state, pool_k=pool_k,
                                         pool_v=pool_v, seq_lens=lens)
        finished: List[Request] = []
        for slot in active:
            req = self.slots[slot]
            t = int(tokens[slot])
            req.generated.append(t)
            if t == tok.EOS or len(req.generated) >= req.max_new:
                req.done = True
                finished.append(req)
                self.state = pc.release_sequence(self.state, self.allocator,
                                                 slot)
                # park the idle slot back on the scratch block
                self.state = dataclasses.replace(
                    self.state,
                    block_tables=self.state.block_tables.at[slot, 0].set(
                        self.trash_block))
                self.slots[slot] = None
        return finished

    # ------------------------------------------------------------------ run
    def run(self, params, key, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        steps = 0
        while (self._pending or any(r is not None
                                    for r in self.slots.values())):
            self._admit(params)
            if not any(r is not None for r in self.slots.values()):
                break
            key, sub = jax.random.split(key)
            done.extend(self.step(params, sub))
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serving loop exceeded max_steps")
        return done
