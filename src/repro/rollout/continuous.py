"""Continuous batching server over the paged KV cache.

Requests are admitted into fixed slots as others finish (so the decode
step compiles once for ``max_seqs``); finished sequences release their
pages back to the allocator. This is the serving loop the paper's rollout
engines (vLLM/SGLang) implement, in-framework.

Supports dense GQA/MHA architectures (the paged pool holds per-layer
K/V), pure-SSM stacks (mamba2 — a constant-size per-slot state pool
instead of KV blocks), and hybrid stacks (zamba2 — SSM state slots plus
the paged pool for the shared attention layers).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.data import tokenizer as tok
from repro.kernels.decode_attn.ops import paged_decode_attention_op
from repro.kernels.prefill_attn.ops import paged_prefill_attention_op
from repro.models import blocks as blk_mod
from repro.models import model as M
from repro.models.attention import decode_attention
from repro.models.layers import (
    apply_rope,
    embed_tokens,
    logits_from_hidden,
    rmsnorm,
)
from repro.models.layers import swiglu
from repro.obs.tracing import annotate, span
from repro.rollout import paged_cache as pc
from repro.rollout.sampler import (
    fused_sample_step,
    greedy_token,
    sample_token,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [P] token ids (unpadded)
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # --- staleness-aware control plane bookkeeping -----------------------
    # behavior logprob of each generated token (under the params that
    # produced its logits) and the weight version of those params: the
    # per-token [B, T] stamps a3po.staleness consumes.
    gen_logp: List[float] = dataclasses.field(default_factory=list)
    token_versions: List[int] = dataclasses.field(default_factory=list)
    priority: int = 0            # scheduler class (lower = more urgent)
    submit_version: int = 0      # weight version when the request arrived
    prefix_hit_tokens: int = 0   # prompt tokens served from the radix cache
    preempt_count: int = 0
    # chunked-prefill cursor: prompt tokens whose K/V is resident in the
    # paged pool (radix hits count). The slot only enters the decode
    # horizon once prefill_done.
    prefill_pos: int = 0
    # lifecycle stamps (control-plane clock — wall by default, virtual
    # under the loadgen replay harness; -1 = unset)
    t_submit: float = -1.0
    t_admit: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0
    # --- multi-tenant / SLO bookkeeping (loadgen harness) ----------------
    tenant: str = ""
    slo_class: str = ""          # SLO class name (stamped by SLO scheduler)
    deadline_s: float = float("inf")  # absolute TTFT deadline (clock time)
    drop_reason: str = ""        # staleness_budget | max_preempts | slo_shed

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= len(self.prompt)

    def min_version(self) -> int:
        return min(self.token_versions) if self.token_versions \
            else self.submit_version

    def reset_generation(self) -> None:
        """Discard sampled state for a fresh restart (preempt/resubmit).

        The first-token stamp is cleared too: a restarted request lost
        its partial generation, so the first token the caller actually
        receives is the one after the restart (TTFT re-observes).
        """
        self.generated = []
        self.gen_logp = []
        self.token_versions = []
        self.done = False
        self.prefill_pos = 0
        self.t_first_token = -1.0


def _token_layer_stack(params, cfg: ModelConfig, lens, tokens, kv,
                       append_attend):
    """One-token transformer stack shared by both decode towers.

    Embeds ``tokens`` [S] and runs the layer stack;
    ``append_attend(li, q, k, v, kv) -> (o, kv)`` owns the KV-cache
    representation — the paged pool for the single-step path, a
    horizon-local contiguous view for the fused loop — so the layer math
    (and hence TPU/off-TPU bit-parity) lives in exactly one place.
    Returns (logits [S, V], kv).
    """
    x = embed_tokens(params["embedding"], tokens[:, None], cfg)[:, 0]

    def layer(carry, xs):
        x, kv = carry
        lp, li = xs
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        ap = lp["attn"]
        q = jnp.einsum("bd,dhk->bhk", h, ap["wq"])
        k = jnp.einsum("bd,dhk->bhk", h, ap["wk"])
        v = jnp.einsum("bd,dhk->bhk", h, ap["wv"])
        if cfg.qkv_bias:
            q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
        # one rope over q‖k: positions (and their sin/cos) are shared
        qk = apply_rope(jnp.concatenate([q, k], axis=1)[:, None],
                        lens[:, None], cfg.rope_theta)[:, 0]
        q, k = qk[:, : q.shape[1]], qk[:, q.shape[1]:]
        o, kv = append_attend(li, q, k, v, kv)
        y = jnp.einsum("bhk,hkd->bd", o, ap["wo"])
        if cfg.parallel_block:
            f = swiglu(lp["ffn"], h)
            x = x + y + f
        else:
            x = x + y
            h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            x = x + swiglu(lp["ffn"], h2)
        return (x, kv), None

    li = jnp.arange(len(cfg.block_kinds()), dtype=jnp.int32)
    # fully unrolled: serving stacks are shallow and the per-iteration
    # scan machinery (dynamic pool slicing) dominates tiny decode matmuls
    (x, kv), _ = jax.lax.scan(layer, (x, kv), (params["blocks"], li),
                              unroll=True)
    x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)[:, 0]
    logits = logits_from_hidden(params["embedding"], x, cfg)
    return logits, kv


def _decode_tower(params, cfg: ModelConfig, pool_k, pool_v, block_tables,
                  lens, tokens, write_block, offset):
    """One-token layer stack over the paged pool.

    Appends each layer's K/V at ``(write_block, offset)`` per slot and
    attends through the block table via ``paged_decode_attention_op``
    (Pallas on TPU, XLA gather elsewhere) -> (logits, pool_k, pool_v).
    Callers choose the write targets: the single-step path writes at the
    current length for every slot; the fused horizon redirects finished
    slots to the scratch block so a masked-out step can never touch live
    pages.
    """
    def append_attend(li, q, k, v, kv):
        pool_k, pool_v = kv
        pool_k = pool_k.at[li, write_block, offset].set(
            k.astype(pool_k.dtype))
        pool_v = pool_v.at[li, write_block, offset].set(
            v.astype(pool_v.dtype))
        # lens + 1: the just-written token is attended (inclusive mask)
        o = paged_decode_attention_op(q, pool_k[li], pool_v[li],
                                      block_tables, lens + 1)
        return o, (pool_k, pool_v)

    logits, (pool_k, pool_v) = _token_layer_stack(
        params, cfg, lens, tokens, (pool_k, pool_v), append_attend)
    return logits, pool_k, pool_v


@functools.partial(jax.jit, static_argnames=("cfg", "trash_block"),
                   donate_argnames=("pool_k", "pool_v"))
def _paged_decode_step(params, cfg: ModelConfig, pool_k, pool_v,
                       block_tables, seq_lens, tokens, active, *,
                       trash_block: int = 0):
    """One token for every slot against the paged pool.

    tokens: [S_max]; active: [S_max] bool — inactive slots (idle, or
    mid-prefill with live pages at their cursor) have their K/V append
    redirected to the scratch block so a batch-wide launch can never
    corrupt pages it doesn't own. Returns (logits [S_max, V], pool_k,
    pool_v).
    """
    bs = pool_k.shape[2]
    safe_tables = jnp.maximum(block_tables, 0)
    blk_idx = seq_lens // bs
    write_block = jnp.take_along_axis(safe_tables, blk_idx[:, None],
                                      axis=1)[:, 0]
    write_block = jnp.where(active, write_block, trash_block)
    offset = jnp.where(active, seq_lens % bs, 0)
    return _decode_tower(params, cfg, pool_k, pool_v, block_tables,
                         seq_lens, tokens, write_block, offset)


def _prefill_tower(params, cfg: ModelConfig, pool_k, pool_v, block_tables,
                   seg_ids, q_pos, kv_lens, tokens, write_block, offset):
    """Chunk-of-tokens layer stack over the paged pool.

    The chunk's ``C`` rows are virtual decode slots: the same
    ``_token_layer_stack`` runs with per-row positions ``q_pos``, each
    layer scatters the chunk's K/V into pool pages at ``(write_block,
    offset)`` in ONE dispatch (padding rows land on scratch), and
    attention walks each row's slot block table via
    ``paged_prefill_attention_op`` — so the per-row math is identical to
    the decode tower and no dense [L, P, KV, hd] intermediate ever
    exists. Returns (logits [C, V], pool_k, pool_v).
    """
    def append_attend(li, q, k, v, kv):
        pool_k, pool_v = kv
        pool_k = pool_k.at[li, write_block, offset].set(
            k.astype(pool_k.dtype))
        pool_v = pool_v.at[li, write_block, offset].set(
            v.astype(pool_v.dtype))
        o = paged_prefill_attention_op(q, pool_k[li], pool_v[li],
                                       block_tables, seg_ids, q_pos,
                                       kv_lens)
        return o, (pool_k, pool_v)

    logits, (pool_k, pool_v) = _token_layer_stack(
        params, cfg, q_pos, tokens, (pool_k, pool_v), append_attend)
    return logits, pool_k, pool_v


@functools.partial(jax.jit, static_argnames=("cfg", "trash_block"),
                   donate_argnames=("pool_k", "pool_v", "next_logits"))
def _paged_prefill_chunk(params, cfg: ModelConfig, pool_k, pool_v,
                         block_tables, seq_lens, next_logits, tokens,
                         seg_ids, q_pos, kv_lens, last_rows, complete,
                         seg_counts, *, trash_block: int):
    """One fixed-shape prefill chunk: C prompt tokens, possibly spanning
    several slots (segment-packed), written straight into pool pages.

    tokens/seg_ids/q_pos: [C] (padding rows carry seg -1); kv_lens [S]
    per-slot resident count *after* this chunk; last_rows/complete/
    seg_counts: [S] — the chunk row holding each slot's final prompt
    token (when ``complete``), whether the slot finishes its prompt here,
    and how many rows belong to it. Completing slots get their
    next-token logits installed; ``seq_lens`` advances by the rows
    written. Compiles once per (C bucket, S) shape.
    """
    bs = pool_k.shape[2]
    safe_tables = jnp.maximum(block_tables, 0)
    row_tables = safe_tables[jnp.maximum(seg_ids, 0)]        # [C, mb]
    blk_idx = jnp.minimum(q_pos // bs, row_tables.shape[1] - 1)
    wb = jnp.take_along_axis(row_tables, blk_idx[:, None], axis=1)[:, 0]
    wb = jnp.where(seg_ids >= 0, wb, trash_block)
    off = jnp.where(seg_ids >= 0, q_pos % bs, 0)
    logits, pool_k, pool_v = _prefill_tower(
        params, cfg, pool_k, pool_v, block_tables, seg_ids, q_pos, kv_lens,
        tokens, wb, off)
    sel = logits[jnp.maximum(last_rows, 0)]                  # [S, V]
    next_logits = jnp.where(complete[:, None],
                            sel.astype(next_logits.dtype), next_logits)
    return next_logits, pool_k, pool_v, seq_lens + seg_counts


@functools.partial(jax.jit, static_argnames=("cfg", "trash_block"),
                   donate_argnames=("pool_k", "pool_v"))
def _dense_prefill(params, cfg: ModelConfig, pool_k, pool_v, tokens,
                   length, table, *, trash_block: int):
    """Whole-sequence dense prefill into pool pages, one scatter.

    tokens [1, Pb] right-padded to a chunk-ladder bucket (so the compile
    shape is the bucket, not the prompt length); length: true prompt
    length; table [max_blocks] this slot's block table. Returns
    (next-token logits [V], pool_k, pool_v) — the K/V of all Pb
    positions lands in the pool via a single batched scatter (padding
    positions on the scratch block) instead of a host loop of per-block
    copies.
    """
    Pb = tokens.shape[1]
    bs = pool_k.shape[2]
    hidden, cache = M.prefill(params, cfg, tokens,
                              lengths=length[None], max_len=Pb)
    k = cache["attn"]["k"][:, 0]  # [L, Pb, KV, hd]
    v = cache["attn"]["v"][:, 0]
    pos = jnp.arange(Pb)
    blk_idx = jnp.minimum(pos // bs, table.shape[0] - 1)
    phys = jnp.where(pos < length, jnp.maximum(table, 0)[blk_idx],
                     trash_block)
    off = jnp.where(pos < length, pos % bs, 0)
    pool_k = pool_k.at[:, phys, off].set(k.astype(pool_k.dtype))
    pool_v = pool_v.at[:, phys, off].set(v.astype(pool_v.dtype))
    h_last = jnp.take(hidden[0], length - 1, axis=0)
    logits = logits_from_hidden(params["embedding"], h_last[None], cfg)[0]
    return logits, pool_k, pool_v


def _decode_tower_view(params, cfg: ModelConfig, view_k, view_v, lens,
                       tokens, rows):
    """Horizon-local variant of ``_decode_tower`` over contiguous views.

    ``view_k``/``view_v`` [L, S, max_blocks*bs, KV, hd] are each slot's
    block-table gather, materialized ONCE per horizon — so the per-token
    hot loop is an in-place append at ``(slot, lens)`` plus dense decode
    attention, with no per-token pool gather/scatter. Identical values to
    the paged path (the view captures exactly what the gather would
    read), hence bit-identical logits.
    """
    # the inclusive valid mask is layer-independent: compute it once
    valid = jnp.arange(view_k.shape[2])[None, :] <= lens[:, None]

    def append_attend(li, q, k, v, kv):
        view_k, view_v = kv
        view_k = view_k.at[li, rows, lens].set(k.astype(view_k.dtype))
        view_v = view_v.at[li, rows, lens].set(v.astype(view_v.dtype))
        o = decode_attention(q, view_k[li], view_v[li], valid)
        return o, (view_k, view_v)

    logits, (view_k, view_v) = _token_layer_stack(
        params, cfg, lens, tokens, (view_k, view_v), append_attend)
    return logits, view_k, view_v


@functools.partial(jax.jit, static_argnames=("cfg", "horizon", "temperature",
                                             "top_p", "greedy",
                                             "trash_block", "use_view"),
                   donate_argnames=("pool_k", "pool_v"))
def _paged_decode_horizon(params, cfg: ModelConfig, pool_k, pool_v,
                          block_tables, seq_lens, next_logits,
                          budget, key, *, trash_block: int, horizon: int,
                          temperature: float, top_p: float, greedy: bool,
                          use_view: Optional[bool] = None):
    """A whole decode horizon as one compiled ``lax.scan``.

    Each iteration samples on device from the carried logits
    (``fused_sample_step``: PAD/zero-mask for finished rows, EOS folded
    into the done flags), appends K/V, and bumps the emitting slots'
    lengths — no host round-trip anywhere inside. ``budget`` [S] caps
    per-slot emissions (a slot's remaining ``max_new``); finished or
    over-budget slots keep decoding masked (their writes land in scratch
    space and their mask is 0). The per-token key schedule is
    ``key, sub = split(key)`` per iteration — exactly the schedule a
    step-by-step driver uses, so seeded sampling is bit-identical to
    ``horizon`` calls of ``step``.

    On TPU the scan attends through the block table with the paged Pallas
    kernel every token (no dense materialization — VMEM streaming is the
    win there). Elsewhere the block table is frozen for the horizon
    anyway, so each slot's KV view is gathered ONCE up front, the scan
    runs on the contiguous views (same values, bit-identical logits), and
    the new K/V is scattered back to the pool in one shot at the end —
    removing the per-token gather/scatter that dominates XLA-CPU decode.

    Returns (packed [3, horizon, S] float32 — tokens / logps / masks,
    drained to host as ONE transfer), plus the updated pool, lengths, and
    next-token logits, which all stay on device.
    """
    bs = pool_k.shape[2]
    S, mb = block_tables.shape
    safe_tables = jnp.maximum(block_tables, 0)
    if use_view is None:
        use_view = jax.default_backend() != "tpu"
    rows = jnp.arange(S)
    done0 = budget <= 0  # inactive slots ship with budget 0

    def sample(logits, done, key, t):
        key, sub = jax.random.split(key)
        done_in = done | (t >= budget)
        token, logp, mask, done_out = fused_sample_step(
            logits, sub, done_in, temperature=temperature, top_p=top_p,
            greedy=greedy)
        done_out = done_out | (t + 1 >= budget)
        return token, logp, mask, done_out, key

    def one_token_paged(carry, t):
        pool_k, pool_v, lens, logits, done, key = carry
        token, logp, mask, done, key = sample(logits, done, key, t)
        emit = mask > 0.0
        blk_idx = lens // bs
        wb = jnp.take_along_axis(safe_tables, blk_idx[:, None],
                                 axis=1)[:, 0]
        wb = jnp.where(emit, wb, trash_block)
        off = jnp.where(emit, lens % bs, 0)
        logits, pool_k, pool_v = _decode_tower(
            params, cfg, pool_k, pool_v, block_tables, lens, token, wb,
            off)
        lens = lens + emit.astype(lens.dtype)
        return (pool_k, pool_v, lens, logits, done, key), (token, logp,
                                                           mask)

    def one_token_view(carry, t):
        view_k, view_v, lens, logits, done, key = carry
        token, logp, mask, done, key = sample(logits, done, key, t)
        # non-emitting slots overwrite their own (never-valid, never
        # written-back) position `lens`; OOB appends are dropped
        logits, view_k, view_v = _decode_tower_view(
            params, cfg, view_k, view_v, lens, token, rows)
        lens = lens + (mask > 0.0).astype(lens.dtype)
        return (view_k, view_v, lens, logits, done, key), (token, logp,
                                                           mask)

    ts = jnp.arange(horizon, dtype=jnp.int32)
    if use_view:
        n_layers = pool_k.shape[0]
        view_k = pool_k[:, safe_tables].reshape(
            n_layers, S, mb * bs, *pool_k.shape[3:])
        view_v = pool_v[:, safe_tables].reshape(
            n_layers, S, mb * bs, *pool_v.shape[3:])
        (view_k, view_v, lens, logits, _, _), (tokens, logps, masks) = \
            jax.lax.scan(one_token_view,
                         (view_k, view_v, seq_lens, next_logits, done0,
                          key), ts)
        # write the horizon's new K/V back to the paged pool in one shot:
        # emissions are a prefix, so token t of slot s sits at view
        # position seq_lens[s] + t; masked rows are parked on the
        # scratch block
        emits = masks > 0.0                              # [H, S]
        pos = seq_lens[None, :] + ts[:, None]            # [H, S]
        vpos = jnp.minimum(pos, mb * bs - 1)
        new_k = view_k[:, rows[None, :], vpos]           # [L, H, S, KV, hd]
        new_v = view_v[:, rows[None, :], vpos]
        blk = safe_tables[rows[None, :], jnp.minimum(pos // bs, mb - 1)]
        blk = jnp.where(emits, blk, trash_block).reshape(-1)
        off = jnp.where(emits, pos % bs, 0).reshape(-1)
        flat = (n_layers, horizon * S) + pool_k.shape[3:]
        pool_k = pool_k.at[:, blk, off].set(new_k.reshape(flat))
        pool_v = pool_v.at[:, blk, off].set(new_v.reshape(flat))
    else:
        (pool_k, pool_v, lens, logits, _, _), (tokens, logps, masks) = \
            jax.lax.scan(one_token_paged,
                         (pool_k, pool_v, seq_lens, next_logits, done0,
                          key), ts)
    # one packed drain: token ids are exact in f32 (vocab << 2**24)
    packed = jnp.stack([tokens.astype(jnp.float32), logps, masks])
    return packed, pool_k, pool_v, lens, logits


# ---------------------------------------------------- multi-architecture
def _multiarch_token_stack(params, cfg: ModelConfig, lens, tokens, conv,
                           state, kv, append_attend, update_mask):
    """One-token stack over SSM/hybrid layer sequences.

    ``conv``/``state`` are the per-slot recurrent pools [n_ssm, S, ...];
    ``update_mask`` [S] gates their update — a masked slot carries its
    state through bit-exactly (the SSM analogue of redirecting KV appends
    to the scratch block). Attention layers (hybrid's shared block) run
    the same math as ``_token_layer_stack``'s body through
    ``append_attend``. Python-unrolled over ``cfg.block_kinds()``: the
    layer sequence is heterogeneous and serving stacks are shallow.
    """
    x = embed_tokens(params["embedding"], tokens[:, None], cfg)[:, 0]
    ssm_params = params["blocks"] if cfg.arch_type == "ssm" \
        else params["ssm_blocks"]
    si = ai = 0
    for kind in cfg.block_kinds():
        if kind == "ssm":
            lp = jax.tree.map(lambda a, i=si: a[i], ssm_params)
            c_in = {"conv": conv[si], "state": state[si]}
            x, _, c_out = blk_mod.ssm_block_decode(lp, x, cfg, c_in)
            m3 = update_mask[:, None, None]
            conv = conv.at[si].set(jnp.where(m3, c_out["conv"], conv[si]))
            state = state.at[si].set(
                jnp.where(m3[..., None], c_out["state"], state[si]))
            si += 1
        else:
            lp = params["shared_attn"]
            h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            ap = lp["attn"]
            q = jnp.einsum("bd,dhk->bhk", h, ap["wq"])
            k = jnp.einsum("bd,dhk->bhk", h, ap["wk"])
            v = jnp.einsum("bd,dhk->bhk", h, ap["wv"])
            if cfg.qkv_bias:
                q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
            qk = apply_rope(jnp.concatenate([q, k], axis=1)[:, None],
                            lens[:, None], cfg.rope_theta)[:, 0]
            q, k = qk[:, : q.shape[1]], qk[:, q.shape[1]:]
            o, kv = append_attend(ai, q, k, v, kv)
            y = jnp.einsum("bhk,hkd->bd", o, ap["wo"])
            if cfg.parallel_block:
                x = x + y + swiglu(lp["ffn"], h)
            else:
                x = x + y
                h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
                x = x + swiglu(lp["ffn"], h2)
            ai += 1
    x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)[:, 0]
    logits = logits_from_hidden(params["embedding"], x, cfg)
    return logits, conv, state, kv


@functools.partial(jax.jit, static_argnames=("cfg", "trash_block"),
                   donate_argnames=("pool_k", "pool_v", "conv", "state"))
def _multiarch_decode_step(params, cfg: ModelConfig, pool_k, pool_v, conv,
                           state, block_tables, seq_lens, tokens, active,
                           *, trash_block: int = 0):
    """SSM/hybrid variant of ``_paged_decode_step``: one token per slot,
    KV appended into the paged pool (hybrid attention layers) and the
    recurrent state pools advanced, with ``active`` gating both."""
    bs = pool_k.shape[2]
    safe_tables = jnp.maximum(block_tables, 0)
    blk_idx = seq_lens // bs
    write_block = jnp.take_along_axis(safe_tables, blk_idx[:, None],
                                      axis=1)[:, 0]
    write_block = jnp.where(active, write_block, trash_block)
    offset = jnp.where(active, seq_lens % bs, 0)

    def append_attend(li, q, k, v, kv):
        pool_k, pool_v = kv
        pool_k = pool_k.at[li, write_block, offset].set(
            k.astype(pool_k.dtype))
        pool_v = pool_v.at[li, write_block, offset].set(
            v.astype(pool_v.dtype))
        o = paged_decode_attention_op(q, pool_k[li], pool_v[li],
                                      block_tables, seq_lens + 1)
        return o, (pool_k, pool_v)

    logits, conv, state, (pool_k, pool_v) = _multiarch_token_stack(
        params, cfg, seq_lens, tokens, conv, state, (pool_k, pool_v),
        append_attend, active)
    return logits, pool_k, pool_v, conv, state


@functools.partial(jax.jit, static_argnames=("cfg", "horizon", "temperature",
                                             "top_p", "greedy",
                                             "trash_block"),
                   donate_argnames=("pool_k", "pool_v", "conv", "state"))
def _multiarch_decode_horizon(params, cfg: ModelConfig, pool_k, pool_v,
                              conv, state, block_tables, seq_lens,
                              next_logits, budget, key, *,
                              trash_block: int, horizon: int,
                              temperature: float, top_p: float,
                              greedy: bool):
    """SSM/hybrid variant of ``_paged_decode_horizon``.

    The recurrent pools ride in the scan carry next to the KV pool; the
    per-token emit mask gates both the KV append (scratch redirect) and
    the state update (masked slots carry state through unchanged), so EOS
    masking, budget exhaustion, and mid-prefill slots behave exactly as
    in the dense horizon. No contiguous-view fast path: SSM state is
    already O(1) per slot, and the hybrid attention layers take the paged
    path on every backend.
    """
    bs = pool_k.shape[2]
    safe_tables = jnp.maximum(block_tables, 0)
    done0 = budget <= 0

    def sample(logits, done, key, t):
        key, sub = jax.random.split(key)
        done_in = done | (t >= budget)
        token, logp, mask, done_out = fused_sample_step(
            logits, sub, done_in, temperature=temperature, top_p=top_p,
            greedy=greedy)
        done_out = done_out | (t + 1 >= budget)
        return token, logp, mask, done_out, key

    def one_token(carry, t):
        pool_k, pool_v, conv, state, lens, logits, done, key = carry
        token, logp, mask, done, key = sample(logits, done, key, t)
        emit = mask > 0.0
        blk_idx = lens // bs
        wb = jnp.take_along_axis(safe_tables, blk_idx[:, None],
                                 axis=1)[:, 0]
        wb = jnp.where(emit, wb, trash_block)
        off = jnp.where(emit, lens % bs, 0)

        def append_attend(li, q, k, v, kv):
            pool_k, pool_v = kv
            pool_k = pool_k.at[li, wb, off].set(k.astype(pool_k.dtype))
            pool_v = pool_v.at[li, wb, off].set(v.astype(pool_v.dtype))
            o = paged_decode_attention_op(q, pool_k[li], pool_v[li],
                                          block_tables, lens + 1)
            return o, (pool_k, pool_v)

        logits, conv, state, (pool_k, pool_v) = _multiarch_token_stack(
            params, cfg, lens, token, conv, state, (pool_k, pool_v),
            append_attend, emit)
        lens = lens + emit.astype(lens.dtype)
        return (pool_k, pool_v, conv, state, lens, logits, done, key), (
            token, logp, mask)

    ts = jnp.arange(horizon, dtype=jnp.int32)
    (pool_k, pool_v, conv, state, lens, logits, _, _), \
        (tokens, logps, masks) = jax.lax.scan(
            one_token, (pool_k, pool_v, conv, state, seq_lens,
                        next_logits, done0, key), ts)
    packed = jnp.stack([tokens.astype(jnp.float32), logps, masks])
    return packed, pool_k, pool_v, conv, state, lens, logits


@functools.partial(jax.jit, static_argnames=("cfg", "trash_block"),
                   donate_argnames=("pool_k", "pool_v", "conv", "state",
                                    "next_logits"))
def _multiarch_prefill_chunk(params, cfg: ModelConfig, pool_k, pool_v,
                             conv, state, block_tables, seq_lens,
                             next_logits, tokens, starts, counts,
                             complete, *, trash_block: int):
    """One fixed-shape SSM/hybrid prefill chunk, one batch row per slot.

    Unlike the attention chunk lane (segment-packed [C] rows), the SSD
    scan is recurrent per sequence, so each prefilling slot owns one row
    of a [S, Cb] batch: ``tokens`` right-padded to the bucket,
    ``counts`` [S] real tokens per row (0 = slot not prefilling),
    ``starts`` [S] the per-slot prompt cursor. SSM layers run the
    chunked SSD scan resuming from (and updating) the slot state pools —
    pad rows carry dt=0 so they freeze the state exactly, and the conv
    tail is sliced at ``counts`` so ragged chunks resume bit-exactly.
    Hybrid attention layers flatten to [S*Cb] virtual decode rows over
    the paged pool, exactly like ``_prefill_tower``. Completing slots
    get next-token logits installed; ``seq_lens`` advances by ``counts``.
    """
    S, Cb = tokens.shape
    bs = pool_k.shape[2]
    row_active = counts > 0
    pad_mask = jnp.arange(Cb)[None, :] < counts[:, None]           # [S, Cb]
    positions = starts[:, None] + jnp.arange(Cb, dtype=jnp.int32)  # [S, Cb]
    kv_lens = seq_lens + counts

    # flattened [S*Cb] rows for the attention layers (hybrid only)
    seg_flat = jnp.where(pad_mask, jnp.arange(S, dtype=jnp.int32)[:, None],
                         -1).reshape(-1)
    pos_flat = positions.reshape(-1)
    safe_tables = jnp.maximum(block_tables, 0)
    row_tables = safe_tables[jnp.maximum(seg_flat, 0)]
    blk_idx = jnp.minimum(pos_flat // bs, row_tables.shape[1] - 1)
    wb = jnp.take_along_axis(row_tables, blk_idx[:, None], axis=1)[:, 0]
    wb = jnp.where(seg_flat >= 0, wb, trash_block)
    off = jnp.where(seg_flat >= 0, pos_flat % bs, 0)

    x = embed_tokens(params["embedding"], tokens, cfg)             # [S,Cb,d]
    ssm_params = params["blocks"] if cfg.arch_type == "ssm" \
        else params["ssm_blocks"]
    si = ai = 0
    for kind in cfg.block_kinds():
        if kind == "ssm":
            lp = jax.tree.map(lambda a, i=si: a[i], ssm_params)
            c_in = {"conv": conv[si], "state": state[si]}
            x, _, c_out = blk_mod.ssm_block_full(
                lp, x, cfg, pad_mask=pad_mask, initial_cache=c_in,
                valid_lens=counts)
            m3 = row_active[:, None, None]
            conv = conv.at[si].set(jnp.where(m3, c_out["conv"], conv[si]))
            state = state.at[si].set(
                jnp.where(m3[..., None], c_out["state"], state[si]))
            si += 1
        else:
            lp = params["shared_attn"]
            h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            ap = lp["attn"]
            q = jnp.einsum("bsd,dhk->bshk", h, ap["wq"])
            k = jnp.einsum("bsd,dhk->bshk", h, ap["wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, ap["wv"])
            if cfg.qkv_bias:
                q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
            qk = apply_rope(jnp.concatenate([q, k], axis=2), positions,
                            cfg.rope_theta)
            q, k = qk[:, :, : q.shape[2]], qk[:, :, q.shape[2]:]

            def flat(t):
                return t.reshape((S * Cb,) + t.shape[2:])

            pool_k = pool_k.at[ai, wb, off].set(
                flat(k).astype(pool_k.dtype))
            pool_v = pool_v.at[ai, wb, off].set(
                flat(v).astype(pool_v.dtype))
            o = paged_prefill_attention_op(flat(q), pool_k[ai], pool_v[ai],
                                           block_tables, seg_flat,
                                           pos_flat, kv_lens)
            y = jnp.einsum("bshk,hkd->bsd",
                           o.reshape((S, Cb) + o.shape[1:]), ap["wo"])
            if cfg.parallel_block:
                x = x + y + swiglu(lp["ffn"], h)
            else:
                x = x + y
                h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
                x = x + swiglu(lp["ffn"], h2)
            ai += 1
    h_last = jnp.take_along_axis(
        x, jnp.maximum(counts - 1, 0)[:, None, None], axis=1)[:, 0]
    h_last = rmsnorm(params["final_norm"], h_last[:, None],
                     cfg.norm_eps)[:, 0]
    logits = logits_from_hidden(params["embedding"], h_last, cfg)
    next_logits = jnp.where(complete[:, None],
                            logits.astype(next_logits.dtype), next_logits)
    return next_logits, pool_k, pool_v, conv, state, seq_lens + counts


class ContinuousBatchingEngine:
    def __init__(self, cfg: ModelConfig, *, max_seqs: int = 8,
                 block_size: int = 16, n_blocks: int = 256,
                 max_blocks_per_seq: int = 16,
                 rl: Optional[RLConfig] = None, greedy: bool = False,
                 prefix_cache=None, decode_horizon: int = 1,
                 prefill_chunk: int = 32, prefill_mode: str = "chunked"):
        assert cfg.arch_type in ("dense", "ssm", "hybrid"), \
            f"paged serving: dense/ssm/hybrid archs, got {cfg.arch_type}"
        assert prefill_mode in ("chunked", "dense"), prefill_mode
        self.cfg = cfg
        self.rl = rl or RLConfig()
        self.greedy = greedy
        self.max_seqs = max_seqs
        # prefill lane: prompts stream through fixed-shape chunk launches
        # of at most ``prefill_chunk`` tokens (short prompts packed
        # together, long prompts resumable via Request.prefill_pos).
        # Launches are padded up the bucket ladder so the chunk step
        # compiles once per bucket, not once per prompt length.
        # ``prefill_mode="dense"`` keeps the legacy inline whole-sequence
        # path (the bench baseline), itself bucket-padded.
        self.prefill_mode = prefill_mode
        self.prefill_chunk = int(prefill_chunk)
        self._chunk_buckets = tuple(sorted(
            {max(8, self.prefill_chunk // 4),
             max(8, self.prefill_chunk // 2), self.prefill_chunk}))
        # tokens decoded per compiled launch: 1 = the per-token fallback
        # (step), >1 = the fused horizon (step_horizon) — host bookkeeping
        # then runs only at horizon boundaries. Callers that observe
        # per-token state between steps (publish-interleaved tests, the
        # per-token baseline bench) keep the default of 1.
        self.decode_horizon = int(decode_horizon)
        # duck-typed serving.prefix_cache.RadixPrefixCache (kept untyped to
        # avoid a rollout -> serving import cycle)
        self.prefix_cache = prefix_cache
        # SSM/hybrid: constant-size per-slot recurrent state rides next to
        # the paged KV pool (which has zero layers for pure-SSM stacks —
        # block/length bookkeeping stays uniform at no memory cost)
        self.n_ssm = sum(1 for k in cfg.block_kinds() if k == "ssm")
        if self.n_ssm:
            assert cfg.moe is None and cfg.frontend is None, \
                "SSM/hybrid serving: no MoE or frontend stacks"
            assert prefill_mode == "chunked", \
                "SSM/hybrid serving requires the chunked prefill lane"
            assert prefix_cache is None, \
                "radix prefix cache shares KV blocks across sequences; " \
                "recurrent SSM state cannot be shared that way"
            self.ssm_cache = pc.init_ssm_state_cache(
                cfg, max_seqs=max_seqs, dtype=jnp.dtype(cfg.dtype))
            self.ssm_pool = pc.SSMSlotPool(max_seqs)
        else:
            self.ssm_cache = None
            self.ssm_pool = None
        # the control plane checks this before attaching a radix cache
        self.supports_prefix_cache = self.n_ssm == 0
        # reserve the last block as the scratch target for idle slots
        self.allocator = pc.BlockAllocator(n_blocks - 1)
        self.trash_block = n_blocks - 1
        self.state = pc.init_paged_cache(
            cfg, n_blocks=n_blocks, block_size=block_size,
            max_seqs=max_seqs, max_blocks_per_seq=max_blocks_per_seq,
            dtype=jnp.dtype(cfg.dtype))
        # idle slots write into the scratch block
        bt = np.full((max_seqs, max_blocks_per_seq), -1, np.int32)
        bt[:, 0] = self.trash_block
        self.state = dataclasses.replace(
            self.state, block_tables=jnp.asarray(bt))
        # host mirrors of block_tables/seq_lens: all decode-path
        # bookkeeping (capacity, CoW, release, headroom) reads these, so
        # the hot loop never blocks on a device readback. Refreshed from
        # the device after admission/prefill (_sync_mirrors), updated
        # in-place at horizon boundaries.
        self._tables = bt
        self._lens = np.zeros((max_seqs,), np.int32)
        self.slots: Dict[int, Optional[Request]] = {
            i: None for i in range(max_seqs)}
        self._pending: List[Request] = []
        self._next_logits = jnp.zeros((max_seqs, cfg.vocab_size),
                                      jnp.float32)
        # weight version of the params that produced each slot's
        # _next_logits row — the stamp for the *next* sampled token
        self._logits_version: List[int] = [0] * max_seqs
        self._rid = 0
        # decode-path telemetry (ServingMetrics folds these into
        # StepRecord.serving): blocking device->host drains, compiled
        # decode launches, and tokens emitted.
        self.host_syncs = 0
        self.decode_launches = 0
        self.tokens_emitted = 0
        self.last_emitted = 0
        # prefill-lane telemetry: chunk launches, prompt tokens computed
        # through the chunk path, and distinct compile shapes seen (the
        # cache-miss counter the bucket-ladder tests pin)
        self.prefill_launches = 0
        self.prefill_chunk_tokens = 0
        self.prefill_compiles = 0
        self._prefill_shapes: set = set()

    # ------------------------------------------------------------- requests
    def submit(self, prompt_ids, max_new: int = 16, *, priority: int = 0,
               submit_version: int = 0) -> int:
        self._rid += 1
        self._pending.append(Request(self._rid, np.asarray(prompt_ids),
                                     max_new, priority=priority,
                                     submit_version=submit_version))
        return self._rid

    def _cache_plan(self, prompt) -> tuple:
        """(n_blocks, n_tokens) the radix cache will actually serve.

        In dense mode, returns (0, 0) when the match is too small to pay
        off: the legacy cached-suffix path costs one full-width decode
        step per remaining prompt token, so a tiny match on a long prompt
        would be far slower than one dense prefill. The chunked lane
        replays a suffix in ceil(len/C) launches, so any match pays.
        """
        if self.prefix_cache is None:
            return 0, 0
        P = len(prompt)
        n_blocks, n_matched = self.prefix_cache.lookup(prompt,
                                                       max_tokens=P - 1)
        if n_matched == 0:
            return 0, 0
        if self.prefill_mode != "chunked":
            suffix = (P - 1) - n_matched
            if suffix > max(2 * self.state.block_size, (P - 1) // 2):
                return 0, 0
        return n_blocks, n_matched

    def blocks_needed(self, prompt, max_new: int) -> int:
        """Fresh blocks a request needs, given current prefix-cache state.

        Reserves headroom for the copy-on-write forks a cached partial
        block can trigger (one for a matched shared tail, one for this
        prompt's own tail once the cache holds a reference to it).
        """
        P = len(prompt)
        bs = self.state.block_size
        total = -(-(P + max_new) // bs)
        if self.prefix_cache is None:
            return total
        n_blocks, n_matched = self._cache_plan(prompt)
        spare = (1 if n_matched % bs else 0) + (1 if P % bs else 0)
        return total - n_blocks + spare

    def _reclaim_headroom(self, n: int = 1) -> None:
        """Evict cache-only blocks so a decode-time alloc (capacity growth
        or CoW fork) cannot OOM while reclaimable blocks exist."""
        if self.prefix_cache is not None and self.allocator.n_free < n:
            self.prefix_cache.evict(n - self.allocator.n_free)

    def decode_block_shortfall(self) -> int:
        """Blocks the next decode launch would need beyond what the pool
        can supply (free + cache-evictable). Mirrors ``_prepare_decode``'s
        need computation — unmapped blocks in each decode-ready slot's
        write range plus a CoW fork for a radix-shared first block — so
        the control plane can *shed* work before the allocator hard-OOMs
        mid-fork (which would desync the host mirrors). 0 when safe.
        """
        bs = self.state.block_size
        mb = self.state.max_blocks
        H = max(self.decode_horizon, 1)
        need = 0
        for slot in self.decode_ready_slots():
            r = self.slots[slot]
            n = min(H, r.max_new - len(r.generated))
            if n <= 0:
                continue
            first, last = pc.write_range(int(self._lens[slot]), n, bs, mb)
            need += int(np.sum(self._tables[slot, first: last + 1] < 0))
            blk = int(self._tables[slot, first])
            if blk >= 0 and self.allocator.refs(blk) > 1:
                need += 1
        supply = self.allocator.n_free
        if self.prefix_cache is not None:
            supply += self.prefix_cache.evictable_count()
        return max(need - supply, 0)

    def free_slots(self) -> List[int]:
        return [s for s, r in self.slots.items() if r is None]

    def decode_ready_slots(self) -> List[int]:
        """Slots whose prompt K/V is fully resident (decode-lane set)."""
        return [s for s, r in self.slots.items()
                if r is not None and r.prefill_done]

    def prefilling_slots(self) -> List[int]:
        return [s for s, r in self.slots.items()
                if r is not None and not r.prefill_done]

    def _admit(self, params, version: int = 0) -> None:
        for slot in self.free_slots():
            if not self._pending:
                break
            nxt = self._pending[0]
            if self.blocks_needed(nxt.prompt, nxt.max_new) \
                    > self.allocator.n_free:
                break
            self._pending.pop(0)
            self.admit_request(params, slot, nxt, version=version)

    def admit_request(self, params, slot: int, req: Request,
                      version: int = 0, *, prefill: bool = True) -> None:
        """Place ``req`` into ``slot`` (control-plane entry).

        ``prefill=True`` (the legacy contract) leaves the slot fully
        prefilled on return — inline for dense mode, by draining the
        chunk lane for chunked mode. The control plane passes
        ``prefill=False`` and streams chunks through ``prefill_step``
        under its per-boundary budget instead, so a long prompt never
        blocks the decode lane for its whole prefill.
        """
        assert self.slots[slot] is None, f"slot {slot} occupied"
        if self.prefill_mode == "dense":
            self.slots[slot] = req
            self._prefill_into(params, slot, req, version=version)
            req.prefill_pos = len(req.prompt)
            self._sync_mirrors()
            return
        self.start_prefill(slot, req, version=version)
        if prefill:
            while not req.prefill_done:
                self.prefill_step(params, version=version, max_chunks=1)

    def start_prefill(self, slot: int, req: Request,
                      version: int = 0) -> None:
        """Map pages for ``req`` (radix prefix included) without running
        any prefill compute; chunk launches stream the rest."""
        assert self.slots[slot] is None, f"slot {slot} occupied"
        self.slots[slot] = req
        P = len(req.prompt)
        matched: List[int] = []
        n_matched = 0
        if self._cache_plan(req.prompt)[1]:
            matched, n_matched = self.prefix_cache.match(req.prompt,
                                                         max_tokens=P - 1)
        if n_matched:
            self.state = pc.map_sequence_prefixed(
                self.state, self.allocator, slot, matched, n_matched,
                P + req.max_new)
        else:
            self.state = pc.map_sequence(self.state, self.allocator, slot,
                                         P + req.max_new)
        req.prefix_hit_tokens = n_matched
        req.prefill_pos = n_matched
        if self.ssm_pool is not None:
            # fresh sequence: map the slot and zero its recurrent state
            self.ssm_pool.map(slot)
            self.ssm_cache = pc.ssm_reset_slots(self.ssm_cache,
                                                np.asarray([slot]))
        self._logits_version[slot] = version
        self._sync_mirrors()

    def prefill_step(self, params, version: int = 0,
                     max_chunks: Optional[int] = None) -> int:
        """Run up to ``max_chunks`` chunk launches over mid-prefill slots
        (all of them when None); returns the number launched."""
        launched = 0
        while max_chunks is None or launched < max_chunks:
            work = self._gather_prefill_work()
            if not work:
                break
            self._prefill_chunk_launch(params, work, version)
            launched += 1
        return launched

    def _gather_prefill_work(self) -> List[tuple]:
        """Pack pending prompt tokens into one chunk: [(slot, start, n)].

        Shortest-remaining-first, so short prompts reach their first
        token fast even while a long prompt is streaming; the long
        prompt takes whatever chunk capacity is left each launch, so it
        still progresses every boundary.

        SSM/hybrid stacks cannot pack segments into one row stream (the
        SSD scan is recurrent per sequence), so each prefilling slot owns
        a batch row instead and advances by up to a full chunk per
        launch.
        """
        if self.n_ssm:
            return [(s, self.slots[s].prefill_pos,
                     min(len(self.slots[s].prompt)
                         - self.slots[s].prefill_pos, self.prefill_chunk))
                    for s in sorted(self.prefilling_slots())]
        order = sorted(
            self.prefilling_slots(),
            key=lambda s: (len(self.slots[s].prompt)
                           - self.slots[s].prefill_pos, s))
        work: List[tuple] = []
        used = 0
        for slot in order:
            r = self.slots[slot]
            take = min(len(r.prompt) - r.prefill_pos,
                       self.prefill_chunk - used)
            if take <= 0:
                break
            work.append((slot, r.prefill_pos, take))
            used += take
        return work

    def _chunk_bucket(self, n: int) -> int:
        """Smallest ladder bucket holding ``n`` tokens (n <= chunk)."""
        for b in self._chunk_buckets:
            if n <= b:
                return b
        return self.prefill_chunk

    def _dense_bucket(self, n: int) -> int:
        """Pad width for a dense whole-sequence prefill: the chunk ladder
        below ``prefill_chunk``, whole chunks above it."""
        if n <= self.prefill_chunk:
            return self._chunk_bucket(n)
        return -(-n // self.prefill_chunk) * self.prefill_chunk

    def _note_compile(self, shape: tuple) -> None:
        if shape not in self._prefill_shapes:
            self._prefill_shapes.add(shape)
            self.prefill_compiles += 1

    def _prefill_chunk_launch(self, params, work: List[tuple],
                              version: int) -> None:
        """One segment-packed chunk launch over ``[(slot, start, n)]``."""
        if self.n_ssm:
            self._multiarch_prefill_launch(params, work, version)
            return
        n_rows = sum(n for _, _, n in work)
        bucket = self._chunk_bucket(n_rows)
        tokens = np.full((bucket,), tok.PAD, np.int32)
        seg = np.full((bucket,), -1, np.int32)
        pos = np.zeros((bucket,), np.int32)
        kv_lens = np.zeros((self.max_seqs,), np.int32)
        last_rows = np.zeros((self.max_seqs,), np.int32)
        complete = np.zeros((self.max_seqs,), bool)
        seg_counts = np.zeros((self.max_seqs,), np.int32)
        row = 0
        for slot, start, n in work:
            r = self.slots[slot]
            tokens[row: row + n] = r.prompt[start: start + n]
            seg[row: row + n] = slot
            pos[row: row + n] = np.arange(start, start + n)
            kv_lens[slot] = start + n
            seg_counts[slot] = n
            if start + n == len(r.prompt):
                complete[slot] = True
                last_rows[slot] = row + n - 1
            row += n
        with span("prefill_chunk", rows=n_rows, bucket=bucket,
                  segments=len(work), version=version,
                  completed=int(complete.sum())):
            # fork the (possibly radix-shared) first write block of each
            # slot, pre-map the rest, push the table mirror once
            self._prepare_decode({slot: n for slot, _, n in work})
            next_logits, pool_k, pool_v, seq_lens = _paged_prefill_chunk(
                params, self.cfg, self.state.pool_k, self.state.pool_v,
                self.state.block_tables, self.state.seq_lens,
                self._next_logits, jnp.asarray(tokens), jnp.asarray(seg),
                jnp.asarray(pos), jnp.asarray(kv_lens),
                jnp.asarray(last_rows), jnp.asarray(complete),
                jnp.asarray(seg_counts), trash_block=self.trash_block)
        self._next_logits = next_logits
        self.state = dataclasses.replace(self.state, pool_k=pool_k,
                                         pool_v=pool_v, seq_lens=seq_lens)
        self.prefill_launches += 1
        self.prefill_chunk_tokens += n_rows
        self._note_compile(("chunk", bucket))
        bs = self.state.block_size
        for slot, start, n in work:
            r = self.slots[slot]
            r.prefill_pos = start + n
            self._lens[slot] += n
            if r.prefill_done:
                self._logits_version[slot] = version
                if self.prefix_cache is not None:
                    n_blocks = -(-len(r.prompt) // bs)
                    self.prefix_cache.insert(
                        r.prompt,
                        [int(b) for b in self._tables[slot][:n_blocks]])

    def _multiarch_prefill_launch(self, params, work: List[tuple],
                                  version: int) -> None:
        """One batched SSM/hybrid prefill launch over ``[(slot, start,
        n)]`` — each slot owns a row of a [max_seqs, bucket] batch."""
        nmax = max(n for _, _, n in work)
        bucket = self._chunk_bucket(nmax)
        S = self.max_seqs
        tokens = np.full((S, bucket), tok.PAD, np.int32)
        starts = np.zeros((S,), np.int32)
        counts = np.zeros((S,), np.int32)
        complete = np.zeros((S,), bool)
        for slot, start, n in work:
            r = self.slots[slot]
            tokens[slot, :n] = r.prompt[start: start + n]
            starts[slot] = start
            counts[slot] = n
            complete[slot] = (start + n == len(r.prompt))
        with span("prefill_chunk", rows=int(counts.sum()), bucket=bucket,
                  segments=len(work), version=version,
                  completed=int(complete.sum())):
            self._prepare_decode({slot: n for slot, _, n in work})
            (next_logits, pool_k, pool_v, conv, state, seq_lens) = \
                _multiarch_prefill_chunk(
                    params, self.cfg, self.state.pool_k,
                    self.state.pool_v, self.ssm_cache.conv,
                    self.ssm_cache.state, self.state.block_tables,
                    self.state.seq_lens, self._next_logits,
                    jnp.asarray(tokens), jnp.asarray(starts),
                    jnp.asarray(counts), jnp.asarray(complete),
                    trash_block=self.trash_block)
        self._next_logits = next_logits
        self.state = dataclasses.replace(self.state, pool_k=pool_k,
                                         pool_v=pool_v, seq_lens=seq_lens)
        self.ssm_cache = pc.SSMStateCache(conv=conv, state=state)
        self.prefill_launches += 1
        self.prefill_chunk_tokens += int(counts.sum())
        self._note_compile(("machunk", bucket))
        for slot, start, n in work:
            r = self.slots[slot]
            r.prefill_pos = start + n
            self._lens[slot] += n
            if r.prefill_done:
                self._logits_version[slot] = version

    def _sync_mirrors(self) -> None:
        """Refresh host mirrors from the device (admission/prefill only —
        the decode loop itself never reads device state back)."""
        self._tables = np.array(self.state.block_tables)
        self._lens = np.array(self.state.seq_lens)

    def _prefill_into(self, params, slot: int, req: Request,
                      version: int = 0) -> None:
        with span("prefill", slot=slot, prompt_tokens=len(req.prompt),
                  version=version) as sp:
            self._prefill_into_impl(params, slot, req, version)
            sp.set(prefix_hit_tokens=req.prefix_hit_tokens)

    def _prefill_into_impl(self, params, slot: int, req: Request,
                           version: int = 0) -> None:
        P = len(req.prompt)
        bs = self.state.block_size
        matched: List[int] = []
        n_matched = 0
        if self._cache_plan(req.prompt)[1]:
            # cap at P-1: the last prompt token always runs through the
            # decode step so the slot has next-token logits to sample from
            matched, n_matched = self.prefix_cache.match(req.prompt,
                                                         max_tokens=P - 1)
        if n_matched:
            self.state = pc.map_sequence_prefixed(
                self.state, self.allocator, slot, matched, n_matched,
                P + req.max_new)
            self._prefill_suffix(params, slot, req.prompt[n_matched:])
        else:
            self.state = pc.map_sequence(self.state, self.allocator, slot,
                                         P + req.max_new)
            # pad to the chunk-bucket ladder (compile per bucket, not per
            # prompt length) and scatter all K/V into pages in one jitted
            # launch — no host block-copy loop
            Pb = self._dense_bucket(P)
            toks = np.full((1, Pb), tok.PAD, np.int32)
            toks[0, :P] = req.prompt
            logits, pool_k, pool_v = _dense_prefill(
                params, self.cfg, self.state.pool_k, self.state.pool_v,
                jnp.asarray(toks), jnp.asarray(P, jnp.int32),
                self.state.block_tables[slot],
                trash_block=self.trash_block)
            self._note_compile(("dense", Pb))
            self.state = dataclasses.replace(
                self.state, pool_k=pool_k, pool_v=pool_v,
                seq_lens=self.state.seq_lens.at[slot].set(P))
            self._next_logits = self._next_logits.at[slot].set(logits)
        req.prefix_hit_tokens = n_matched
        if self.prefix_cache is not None:
            table = np.asarray(self.state.block_tables[slot])
            n_prompt_blocks = -(-P // bs)
            self.prefix_cache.insert(
                req.prompt, [int(b) for b in table[:n_prompt_blocks]])
        self._logits_version[slot] = version

    def _prefill_suffix(self, params, slot: int, suffix) -> None:
        """Prefill the uncached prompt tail through the paged decode path.

        The cached prefix KV is already resident in this slot's blocks, so
        each remaining prompt token is one decode step that attends over
        the shared pages. Every *other* slot is pointed at the scratch
        block for the duration so its pool pages and sampled logits are
        untouched.
        """
        for t in suffix:
            self._reclaim_headroom(2)  # capacity growth + possible fork
            self.state = pc.ensure_capacity(self.state, self.allocator,
                                            slot)
            self.state = pc.ensure_writable(self.state, self.allocator,
                                            slot)
            bt = np.full((self.max_seqs, self.state.max_blocks), -1,
                         np.int32)
            bt[:, 0] = self.trash_block
            bt[slot] = np.asarray(self.state.block_tables[slot])
            lens = np.zeros((self.max_seqs,), np.int32)
            lens[slot] = int(self.state.seq_lens[slot])
            tokens = np.full((self.max_seqs,), int(t), np.int32)
            one_hot = np.zeros((self.max_seqs,), bool)
            one_hot[slot] = True
            logits, pool_k, pool_v = _paged_decode_step(
                params, self.cfg, self.state.pool_k, self.state.pool_v,
                jnp.asarray(bt), jnp.asarray(lens), jnp.asarray(tokens),
                jnp.asarray(one_hot), trash_block=self.trash_block)
            self.state = dataclasses.replace(
                self.state, pool_k=pool_k, pool_v=pool_v,
                seq_lens=self.state.seq_lens.at[slot].add(1))
            self._next_logits = self._next_logits.at[slot].set(logits[slot])

    # ----------------------------------------------------------------- step
    def _prepare_decode(self, slot_tokens: Dict[int, int]) -> None:
        """Horizon-boundary bookkeeping, entirely on the host mirrors.

        Reclaims allocator headroom for everything the next
        ``slot_tokens[slot]`` writes of each slot may need, forks the
        first write block of any slot resuming on radix-cache-shared
        pages (only that block can be shared: later blocks in the write
        range are always freshly allocated), and pre-maps every missing
        block — then pushes the block-table mirror to the device at most
        once. No device readback anywhere.
        """
        bs = self.state.block_size
        mb = self.state.max_blocks
        need = 0
        for slot, n in slot_tokens.items():
            if n <= 0:
                continue
            first, last = pc.write_range(int(self._lens[slot]), n, bs, mb)
            need += int(np.sum(self._tables[slot, first: last + 1] < 0))
            blk = int(self._tables[slot, first])
            if blk >= 0 and self.allocator.refs(blk) > 1:
                need += 1  # CoW fork below
        self._reclaim_headroom(need)
        dirty = False
        for slot, n in slot_tokens.items():
            if n <= 0:
                continue
            first = int(self._lens[slot]) // bs
            blk = int(self._tables[slot, first])
            if blk >= 0 and self.allocator.refs(blk) > 1:
                self.state, new = pc.fork_block(self.state, self.allocator,
                                                blk)
                self._tables[slot, first] = new
                dirty = True
        dirty |= pc.alloc_horizon_blocks(self.allocator, self._tables,
                                         self._lens, slot_tokens, bs)
        if __debug__:
            # every active slot's upcoming write positions must be mapped:
            # an unmapped write is silently routed to the scratch block by
            # write_token/_decode_tower, so catch the bookkeeping bug here
            for slot, n in slot_tokens.items():
                if n <= 0:
                    continue
                first, last = pc.write_range(int(self._lens[slot]), n, bs,
                                             mb)
                tab = self._tables[slot, first: last + 1]
                assert (tab >= 0).all(), (
                    f"slot {slot}: unmapped write blocks {tab.tolist()} "
                    f"in range [{first}, {last}]")
        if dirty:
            self.state = dataclasses.replace(
                self.state, block_tables=jnp.asarray(self._tables))

    def step(self, params, key, version: int = 0) -> List[Request]:
        """One decode step for every active slot; returns finished reqs.

        ``params``/``version`` may change between calls (interruptible
        generation): in-flight sequences keep their paged KV and resume
        under the new weights, and every sampled token is stamped with the
        version of the params that produced its logits.

        This is the per-token fallback path (``decode_horizon=1``): it
        pays one sampled-token drain per token. ``step_horizon`` amortizes
        that over a whole compiled horizon.
        """
        with span("decode_step", version=version) as sp:
            finished = self._step_impl(params, key, version)
            sp.set(tokens=self.last_emitted, finished=len(finished))
        return finished

    def _step_impl(self, params, key, version: int = 0) -> List[Request]:
        # mid-prefill slots are not decode-ready: they have no sampled
        # logits yet and their pages (possibly radix-shared) sit at the
        # write cursor — they stay masked out of the launch entirely
        active = self.decode_ready_slots()
        if not active:
            return []
        if self.greedy:
            tokens, logps = greedy_token(self._next_logits)
        else:
            tokens, logps = sample_token(self._next_logits, key,
                                         temperature=self.rl.temperature,
                                         top_p=self.rl.top_p)
        tokens = np.asarray(tokens)
        logps = np.asarray(logps)
        self.host_syncs += 2  # token + logp drains, one per token decoded
        self.decode_launches += 1
        self._prepare_decode({slot: 1 for slot in active})
        active_arr = np.zeros((self.max_seqs,), bool)
        active_arr[active] = True
        if self.n_ssm:
            logits, pool_k, pool_v, conv, state = _multiarch_decode_step(
                params, self.cfg, self.state.pool_k, self.state.pool_v,
                self.ssm_cache.conv, self.ssm_cache.state,
                self.state.block_tables, self.state.seq_lens,
                jnp.asarray(tokens), jnp.asarray(active_arr),
                trash_block=self.trash_block)
            self.ssm_cache = pc.SSMStateCache(conv=conv, state=state)
        else:
            logits, pool_k, pool_v = _paged_decode_step(
                params, self.cfg, self.state.pool_k, self.state.pool_v,
                self.state.block_tables, self.state.seq_lens,
                jnp.asarray(tokens), jnp.asarray(active_arr),
                trash_block=self.trash_block)
        # mid-prefill rows of _next_logits become garbage here, which is
        # fine: they are only ever read after their completion chunk
        # overwrites them (completion always precedes decode-readiness)
        self._next_logits = logits
        # bump all active lens with a single vectorized update
        self.state = dataclasses.replace(
            self.state, pool_k=pool_k, pool_v=pool_v,
            seq_lens=self.state.seq_lens
            + jnp.asarray(active_arr, jnp.int32))
        self._lens += active_arr
        self.last_emitted = len(active)
        self.tokens_emitted += len(active)
        finished: List[Request] = []
        for slot in active:
            req = self.slots[slot]
            t = int(tokens[slot])
            req.generated.append(t)
            req.gen_logp.append(float(logps[slot]))
            req.token_versions.append(int(self._logits_version[slot]))
            if t == tok.EOS or len(req.generated) >= req.max_new:
                req.done = True
                finished.append(req)
                self.release_slot(slot)
        # logits computed this step came from `params`
        for slot in active:
            if self.slots.get(slot) is not None:
                self._logits_version[slot] = version
        return finished

    def step_horizon(self, params, key, version: int = 0) -> List[Request]:
        """Decode up to ``decode_horizon`` tokens per active slot in one
        compiled launch; returns finished reqs.

        Sampling, paged KV appends, EOS done-masking, and length bumps
        all run inside the jitted scan; tokens/logps/masks drain to the
        host as ONE packed transfer per horizon (vs ~2 per token for
        ``step``). Host bookkeeping — capacity, CoW, slot release, stamps
        — happens only here, at the boundary. Token 0 of the horizon is
        stamped with the version that produced the carried-in logits;
        later tokens with ``version`` (the params decoding this horizon),
        exactly as ``horizon`` per-token steps would stamp them.
        """
        with span("decode_horizon", horizon=self.decode_horizon,
                  version=version) as sp:
            finished = self._step_horizon_impl(params, key, version)
            sp.set(tokens=self.last_emitted, finished=len(finished))
        return finished

    def _step_horizon_impl(self, params, key,
                           version: int = 0) -> List[Request]:
        H = self.decode_horizon
        # decode lane only: mid-prefill slots keep budget 0 (the scan's
        # emit mask already parks zero-budget writes on scratch), and
        # their garbage _next_logits rows are rewritten at completion
        active = {s: self.slots[s] for s in self.decode_ready_slots()}
        if not active:
            return []
        budget = np.zeros((self.max_seqs,), np.int32)
        for s, r in active.items():
            budget[s] = min(H, r.max_new - len(r.generated))
        self._prepare_decode({s: int(budget[s]) for s in active})
        with annotate("decode_horizon"):
            if self.n_ssm:
                (packed, pool_k, pool_v, conv, state, lens, logits) = \
                    _multiarch_decode_horizon(
                        params, self.cfg, self.state.pool_k,
                        self.state.pool_v, self.ssm_cache.conv,
                        self.ssm_cache.state, self.state.block_tables,
                        self.state.seq_lens, self._next_logits,
                        jnp.asarray(budget), key,
                        trash_block=self.trash_block, horizon=H,
                        temperature=self.rl.temperature,
                        top_p=self.rl.top_p, greedy=self.greedy)
                self.ssm_cache = pc.SSMStateCache(conv=conv, state=state)
            else:
                packed, pool_k, pool_v, lens, logits = \
                    _paged_decode_horizon(
                        params, self.cfg, self.state.pool_k,
                        self.state.pool_v, self.state.block_tables,
                        self.state.seq_lens, self._next_logits,
                        jnp.asarray(budget), key,
                        trash_block=self.trash_block, horizon=H,
                        temperature=self.rl.temperature,
                        top_p=self.rl.top_p, greedy=self.greedy)
        self.state = dataclasses.replace(self.state, pool_k=pool_k,
                                         pool_v=pool_v, seq_lens=lens)
        self._next_logits = logits
        drained = np.asarray(packed)  # the one blocking drain per horizon
        self.host_syncs += 1
        self.decode_launches += 1
        tokens = drained[0].astype(np.int64)
        logps, masks = drained[1], drained[2]
        # emissions are a prefix per slot (done is sticky), so the mask sum
        # is the emitted count — no per-token host loop
        n_emit = masks.sum(axis=0).astype(np.int64)
        finished: List[Request] = []
        released: List[int] = []
        for s, r in active.items():
            n = int(n_emit[s])
            if n:
                r.generated.extend(tokens[:n, s].tolist())
                r.gen_logp.extend(logps[:n, s].tolist())
                r.token_versions.append(int(self._logits_version[s]))
                r.token_versions.extend([version] * (n - 1))
            self._lens[s] += n
            if (n and r.generated[-1] == tok.EOS) \
                    or len(r.generated) >= r.max_new:
                r.done = True
                finished.append(r)
                released.append(s)
            else:
                self._logits_version[s] = version
        if released:
            # free all finished slots' pages with ONE device update (vs a
            # per-slot release_slot dispatch pair)
            for s in released:
                self._release_host(s)
            idx = jnp.asarray(np.asarray(released, np.int32))
            self.state = dataclasses.replace(
                self.state,
                block_tables=self.state.block_tables.at[idx].set(
                    jnp.asarray(self._tables[released])),
                seq_lens=self.state.seq_lens.at[idx].set(0))
        self.last_emitted = int(n_emit.sum())
        self.tokens_emitted += self.last_emitted
        return finished

    def _release_host(self, slot: int) -> None:
        """Host half of a slot release: return pages to the allocator and
        reset the mirrors + slot bookkeeping (callers push to device)."""
        self.allocator.release(
            [int(b) for b in self._tables[slot] if b >= 0])
        if self.ssm_pool is not None:
            # stale recurrent state stays in the pool; the next map of
            # this slot zeroes it (ssm_reset_slots in start_prefill)
            self.ssm_pool.release(slot)
        self._tables[slot] = -1
        self._tables[slot, 0] = self.trash_block
        self._lens[slot] = 0
        self.slots[slot] = None
        self._logits_version[slot] = 0

    def release_slot(self, slot: int) -> Optional[Request]:
        """Free a slot's pages (finish or preemption) and park it.

        Works off the host block-table mirror — no device readback — and
        parks the idle slot back on the scratch block.
        """
        req = self.slots[slot]
        self._release_host(slot)
        self.state = dataclasses.replace(
            self.state,
            block_tables=self.state.block_tables.at[slot].set(
                jnp.asarray(self._tables[slot])),
            seq_lens=self.state.seq_lens.at[slot].set(0))
        return req

    # ------------------------------------------------------------------ run
    def run(self, params, key, max_steps: int = 10_000) -> List[Request]:
        """Drive admission + decode to completion. With ``decode_horizon``
        > 1 each iteration is a fused horizon (``max_steps`` counts
        launches, not tokens)."""
        done: List[Request] = []
        steps = 0
        while (self._pending or any(r is not None
                                    for r in self.slots.values())):
            self._admit(params)
            if not any(r is not None for r in self.slots.values()):
                break
            key, sub = jax.random.split(key)
            if self.decode_horizon > 1:
                done.extend(self.step_horizon(params, sub))
            else:
                done.extend(self.step(params, sub))
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serving loop exceeded max_steps")
        return done
