"""Continuous batching server over the paged KV cache.

Requests are admitted into fixed slots as others finish (so the decode
step compiles once for ``max_seqs``); finished sequences release their
pages back to the allocator. This is the serving loop the paper's rollout
engines (vLLM/SGLang) implement, in-framework.

Supports dense GQA/MHA architectures (the paged pool holds per-layer K/V).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.data import tokenizer as tok
from repro.models import model as M
from repro.models.attention import decode_attention
from repro.models.layers import (
    apply_rope,
    embed_tokens,
    logits_from_hidden,
    rmsnorm,
)
from repro.models.layers import swiglu
from repro.rollout import paged_cache as pc
from repro.rollout.sampler import greedy_token, sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [P] token ids (unpadded)
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # --- staleness-aware control plane bookkeeping -----------------------
    # behavior logprob of each generated token (under the params that
    # produced its logits) and the weight version of those params: the
    # per-token [B, T] stamps a3po.staleness consumes.
    gen_logp: List[float] = dataclasses.field(default_factory=list)
    token_versions: List[int] = dataclasses.field(default_factory=list)
    priority: int = 0            # scheduler class (lower = more urgent)
    submit_version: int = 0      # weight version when the request arrived
    prefix_hit_tokens: int = 0   # prompt tokens served from the radix cache
    preempt_count: int = 0

    def min_version(self) -> int:
        return min(self.token_versions) if self.token_versions \
            else self.submit_version

    def reset_generation(self) -> None:
        """Discard sampled state for a fresh restart (preempt/resubmit)."""
        self.generated = []
        self.gen_logp = []
        self.token_versions = []
        self.done = False


@functools.partial(jax.jit, static_argnames=("cfg",))
def _paged_decode_step(params, cfg: ModelConfig, pool_k, pool_v,
                       block_tables, seq_lens, tokens):
    """One token for every slot against the paged pool.

    tokens: [S_max]; returns (logits [S_max, V], pool_k, pool_v).
    """
    bs = pool_k.shape[2]
    n_slots, max_blocks = block_tables.shape
    x = embed_tokens(params["embedding"], tokens[:, None], cfg)[:, 0]
    lens = seq_lens
    safe_tables = jnp.maximum(block_tables, 0)

    blk_idx = lens // bs
    offset = lens % bs
    write_block = jnp.take_along_axis(safe_tables, blk_idx[:, None],
                                      axis=1)[:, 0]

    def layer(carry, xs):
        x, pool_k, pool_v = carry
        lp, li = xs
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        ap = lp["attn"]
        q = jnp.einsum("bd,dhk->bhk", h, ap["wq"])
        k = jnp.einsum("bd,dhk->bhk", h, ap["wk"])
        v = jnp.einsum("bd,dhk->bhk", h, ap["wv"])
        if cfg.qkv_bias:
            q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
        q = apply_rope(q[:, None], lens[:, None], cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], lens[:, None], cfg.rope_theta)[:, 0]

        pool_k = pool_k.at[li, write_block, offset].set(
            k.astype(pool_k.dtype))
        pool_v = pool_v.at[li, write_block, offset].set(
            v.astype(pool_v.dtype))

        kv_k = pool_k[li][safe_tables].reshape(
            n_slots, max_blocks * bs, *pool_k.shape[3:])
        kv_v = pool_v[li][safe_tables].reshape(
            n_slots, max_blocks * bs, *pool_v.shape[3:])
        valid = jnp.arange(max_blocks * bs)[None, :] <= lens[:, None]
        o = decode_attention(q, kv_k, kv_v, valid)
        y = jnp.einsum("bhk,hkd->bd", o, ap["wo"])
        if cfg.parallel_block:
            f = swiglu(lp["ffn"], h)
            x = x + y + f
        else:
            x = x + y
            h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            x = x + swiglu(lp["ffn"], h2)
        return (x, pool_k, pool_v), None

    li = jnp.arange(len(cfg.block_kinds()), dtype=jnp.int32)
    (x, pool_k, pool_v), _ = jax.lax.scan(
        layer, (x, pool_k, pool_v), (params["blocks"], li))
    x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)[:, 0]
    logits = logits_from_hidden(params["embedding"], x, cfg)
    return logits, pool_k, pool_v


class ContinuousBatchingEngine:
    def __init__(self, cfg: ModelConfig, *, max_seqs: int = 8,
                 block_size: int = 16, n_blocks: int = 256,
                 max_blocks_per_seq: int = 16,
                 rl: Optional[RLConfig] = None, greedy: bool = False,
                 prefix_cache=None):
        assert cfg.arch_type in ("dense",), "paged serving: dense archs"
        self.cfg = cfg
        self.rl = rl or RLConfig()
        self.greedy = greedy
        self.max_seqs = max_seqs
        # duck-typed serving.prefix_cache.RadixPrefixCache (kept untyped to
        # avoid a rollout -> serving import cycle)
        self.prefix_cache = prefix_cache
        # reserve the last block as the scratch target for idle slots
        self.allocator = pc.BlockAllocator(n_blocks - 1)
        self.trash_block = n_blocks - 1
        self.state = pc.init_paged_cache(
            cfg, n_blocks=n_blocks, block_size=block_size,
            max_seqs=max_seqs, max_blocks_per_seq=max_blocks_per_seq,
            dtype=jnp.dtype(cfg.dtype))
        # idle slots write into the scratch block
        bt = np.full((max_seqs, max_blocks_per_seq), -1, np.int32)
        bt[:, 0] = self.trash_block
        self.state = dataclasses.replace(
            self.state, block_tables=jnp.asarray(bt))
        self.slots: Dict[int, Optional[Request]] = {
            i: None for i in range(max_seqs)}
        self._pending: List[Request] = []
        self._next_logits = jnp.zeros((max_seqs, cfg.vocab_size),
                                      jnp.float32)
        # weight version of the params that produced each slot's
        # _next_logits row — the stamp for the *next* sampled token
        self._logits_version: List[int] = [0] * max_seqs
        self._rid = 0

    # ------------------------------------------------------------- requests
    def submit(self, prompt_ids, max_new: int = 16, *, priority: int = 0,
               submit_version: int = 0) -> int:
        self._rid += 1
        self._pending.append(Request(self._rid, np.asarray(prompt_ids),
                                     max_new, priority=priority,
                                     submit_version=submit_version))
        return self._rid

    def _cache_plan(self, prompt) -> tuple:
        """(n_blocks, n_tokens) the radix cache will actually serve.

        Returns (0, 0) when the match is too small to pay off: the cached
        suffix path costs one full-width decode step per remaining prompt
        token, so a tiny match on a long prompt would be far slower than
        one dense prefill.
        """
        if self.prefix_cache is None:
            return 0, 0
        P = len(prompt)
        n_blocks, n_matched = self.prefix_cache.lookup(prompt,
                                                       max_tokens=P - 1)
        suffix = (P - 1) - n_matched
        if n_matched == 0 or suffix > max(2 * self.state.block_size,
                                          (P - 1) // 2):
            return 0, 0
        return n_blocks, n_matched

    def blocks_needed(self, prompt, max_new: int) -> int:
        """Fresh blocks a request needs, given current prefix-cache state.

        Reserves headroom for the copy-on-write forks a cached partial
        block can trigger (one for a matched shared tail, one for this
        prompt's own tail once the cache holds a reference to it).
        """
        P = len(prompt)
        bs = self.state.block_size
        total = -(-(P + max_new) // bs)
        if self.prefix_cache is None:
            return total
        n_blocks, n_matched = self._cache_plan(prompt)
        spare = (1 if n_matched % bs else 0) + (1 if P % bs else 0)
        return total - n_blocks + spare

    def _reclaim_headroom(self, n: int = 1) -> None:
        """Evict cache-only blocks so a decode-time alloc (capacity growth
        or CoW fork) cannot OOM while reclaimable blocks exist."""
        if self.prefix_cache is not None and self.allocator.n_free < n:
            self.prefix_cache.evict(n - self.allocator.n_free)

    def free_slots(self) -> List[int]:
        return [s for s, r in self.slots.items() if r is None]

    def _admit(self, params, version: int = 0) -> None:
        for slot in self.free_slots():
            if not self._pending:
                break
            nxt = self._pending[0]
            if self.blocks_needed(nxt.prompt, nxt.max_new) \
                    > self.allocator.n_free:
                break
            self._pending.pop(0)
            self.admit_request(params, slot, nxt, version=version)

    def admit_request(self, params, slot: int, req: Request,
                      version: int = 0) -> None:
        """Place ``req`` into ``slot`` and prefill (control-plane entry)."""
        assert self.slots[slot] is None, f"slot {slot} occupied"
        self.slots[slot] = req
        self._prefill_into(params, slot, req, version=version)

    def _prefill_into(self, params, slot: int, req: Request,
                      version: int = 0) -> None:
        P = len(req.prompt)
        bs = self.state.block_size
        matched: List[int] = []
        n_matched = 0
        if self._cache_plan(req.prompt)[1]:
            # cap at P-1: the last prompt token always runs through the
            # decode step so the slot has next-token logits to sample from
            matched, n_matched = self.prefix_cache.match(req.prompt,
                                                         max_tokens=P - 1)
        if n_matched:
            self.state = pc.map_sequence_prefixed(
                self.state, self.allocator, slot, matched, n_matched,
                P + req.max_new)
            self._prefill_suffix(params, slot, req.prompt[n_matched:])
        else:
            self.state = pc.map_sequence(self.state, self.allocator, slot,
                                         P + req.max_new)
            toks = jnp.asarray(req.prompt)[None, :]
            hidden, cache = M.prefill(params, self.cfg, toks, max_len=P)
            # copy dense prefill K/V into this sequence's pages
            table = np.asarray(self.state.block_tables[slot])
            k = cache["attn"]["k"][:, 0]  # [L, P, KV, hd]
            v = cache["attn"]["v"][:, 0]
            pool_k, pool_v = self.state.pool_k, self.state.pool_v
            for start in range(0, P, bs):
                blk = int(table[start // bs])
                n = min(bs, P - start)
                pool_k = pool_k.at[:, blk, :n].set(k[:, start:start + n])
                pool_v = pool_v.at[:, blk, :n].set(v[:, start:start + n])
            self.state = dataclasses.replace(
                self.state, pool_k=pool_k, pool_v=pool_v,
                seq_lens=self.state.seq_lens.at[slot].set(P))
            logits = logits_from_hidden(params["embedding"], hidden[:, -1],
                                        self.cfg)
            self._next_logits = self._next_logits.at[slot].set(logits[0])
        req.prefix_hit_tokens = n_matched
        if self.prefix_cache is not None:
            table = np.asarray(self.state.block_tables[slot])
            n_prompt_blocks = -(-P // bs)
            self.prefix_cache.insert(
                req.prompt, [int(b) for b in table[:n_prompt_blocks]])
        self._logits_version[slot] = version

    def _prefill_suffix(self, params, slot: int, suffix) -> None:
        """Prefill the uncached prompt tail through the paged decode path.

        The cached prefix KV is already resident in this slot's blocks, so
        each remaining prompt token is one decode step that attends over
        the shared pages. Every *other* slot is pointed at the scratch
        block for the duration so its pool pages and sampled logits are
        untouched.
        """
        for t in suffix:
            self._reclaim_headroom(2)  # capacity growth + possible fork
            self.state = pc.ensure_capacity(self.state, self.allocator,
                                            slot)
            self.state = pc.ensure_writable(self.state, self.allocator,
                                            slot)
            bt = np.full((self.max_seqs, self.state.max_blocks), -1,
                         np.int32)
            bt[:, 0] = self.trash_block
            bt[slot] = np.asarray(self.state.block_tables[slot])
            lens = np.zeros((self.max_seqs,), np.int32)
            lens[slot] = int(self.state.seq_lens[slot])
            tokens = np.full((self.max_seqs,), int(t), np.int32)
            logits, pool_k, pool_v = _paged_decode_step(
                params, self.cfg, self.state.pool_k, self.state.pool_v,
                jnp.asarray(bt), jnp.asarray(lens), jnp.asarray(tokens))
            self.state = dataclasses.replace(
                self.state, pool_k=pool_k, pool_v=pool_v,
                seq_lens=self.state.seq_lens.at[slot].add(1))
            self._next_logits = self._next_logits.at[slot].set(logits[slot])

    # ----------------------------------------------------------------- step
    def step(self, params, key, version: int = 0) -> List[Request]:
        """One decode step for every active slot; returns finished reqs.

        ``params``/``version`` may change between calls (interruptible
        generation): in-flight sequences keep their paged KV and resume
        under the new weights, and every sampled token is stamped with the
        version of the params that produced its logits.
        """
        if self.greedy:
            tokens, logps = greedy_token(self._next_logits)
        else:
            tokens, logps = sample_token(self._next_logits, key,
                                         temperature=self.rl.temperature,
                                         top_p=self.rl.top_p)
        tokens = np.asarray(tokens)
        logps = np.asarray(logps)
        active = [s for s, r in self.slots.items() if r is not None]
        for slot in active:
            self._reclaim_headroom(2)  # capacity growth + possible fork
            self.state = pc.ensure_capacity(self.state, self.allocator,
                                            slot)
            # CoW guard: never write into a radix-cache-shared block
            self.state = pc.ensure_writable(self.state, self.allocator,
                                            slot)
        logits, pool_k, pool_v = _paged_decode_step(
            params, self.cfg, self.state.pool_k, self.state.pool_v,
            self.state.block_tables, self.state.seq_lens,
            jnp.asarray(tokens))
        self._next_logits = logits
        # bump active lens only
        lens = self.state.seq_lens
        for slot in active:
            lens = lens.at[slot].add(1)
        self.state = dataclasses.replace(self.state, pool_k=pool_k,
                                         pool_v=pool_v, seq_lens=lens)
        finished: List[Request] = []
        for slot in active:
            req = self.slots[slot]
            t = int(tokens[slot])
            req.generated.append(t)
            req.gen_logp.append(float(logps[slot]))
            req.token_versions.append(int(self._logits_version[slot]))
            if t == tok.EOS or len(req.generated) >= req.max_new:
                req.done = True
                finished.append(req)
                self.release_slot(slot)
        # logits computed this step came from `params`
        for slot in active:
            if self.slots.get(slot) is not None:
                self._logits_version[slot] = version
        return finished

    def release_slot(self, slot: int) -> Optional[Request]:
        """Free a slot's pages (finish or preemption) and park it."""
        req = self.slots[slot]
        self.state = pc.release_sequence(self.state, self.allocator, slot)
        # park the idle slot back on the scratch block
        self.state = dataclasses.replace(
            self.state,
            block_tables=self.state.block_tables.at[slot, 0].set(
                self.trash_block))
        self.slots[slot] = None
        self._logits_version[slot] = 0
        return req

    # ------------------------------------------------------------------ run
    def run(self, params, key, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        steps = 0
        while (self._pending or any(r is not None
                                    for r in self.slots.values())):
            self._admit(params)
            if not any(r is not None for r in self.slots.values()):
                break
            key, sub = jax.random.split(key)
            done.extend(self.step(params, sub))
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serving loop exceeded max_steps")
        return done
