"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers + compiles.

MUST set XLA_FLAGS before any jax import: the production meshes need 512
placeholder host devices. Do not import this module from code that wants
real single-device execution (tests/benches import repro.* directly).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch codeqwen1.5-7b \
      --shape train_4k [--multi-pod] [--all]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import RLConfig, SHAPES  # noqa: E402
from repro.configs.registry import get_config, list_archs  # noqa: E402
from repro.distributed.hlo_analysis import roofline_terms  # noqa: E402
from repro.distributed.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.distributed.sharding import ShardingEnv, use_sharding  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.obs.runlog import RunLogger  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        try:
            out[attr] = int(getattr(mem, attr))
        except Exception:  # noqa: BLE001
            pass
    return out


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               algo="a3po", fsdp: bool = True,
               save: bool = True, verbose: bool = True,
               rules=None, hoist_gather: bool = False,
               kv_seq_shard: bool = False, zero1: bool = False,
               tp_fallback: bool = False, ep_moe: bool = False,
               num_microbatches: int = 8, prefill_microbatches: int = 1,
               tag_suffix: str = "", run_logger: RunLogger = None) -> dict:
    from repro.core.algorithms import resolve_algorithm
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rl = RLConfig()
    algo = resolve_algorithm(algo, rl)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    if kv_seq_shard:
        # §Perf lever: shard the decode KV cache along the sequence axis
        # (GSPMD all-reduces the softmax partials) — rescues archs whose
        # kv_heads don't divide the model axis from cache replication.
        from repro.distributed.sharding import DEFAULT_RULES
        rules = tuple(r for r in (rules or DEFAULT_RULES)
                      if r[0] != "kv_seq") + (("kv_seq", "model"),)
    env = (ShardingEnv(mesh, fsdp=fsdp, tp_fallback=tp_fallback)
           if rules is None
           else ShardingEnv(mesh, rules=rules, fsdp=fsdp,
                            tp_fallback=tp_fallback))
    env.ep_shard_map = ep_moe

    specs = steps.input_specs(cfg, shape)
    if shape.kind == "train":
        step = steps.make_train_step(cfg, rl, algo,
                                     num_microbatches=num_microbatches,
                                     hoist_fsdp_gather=hoist_gather)
    elif shape.kind == "prefill" and prefill_microbatches > 1:
        step = steps.make_prefill_step(cfg, shape, prefill_microbatches)
    else:
        step = steps.make_step(cfg, shape, rl, algo)
    params_abs = M.abstract_params(cfg)
    param_sh = M.param_shardings(cfg, env)
    batch_sh = steps.batch_shardings(cfg, shape, env, specs)
    opt_env = env
    if zero1:
        # §Perf lever (ZeRO-1): weights replicated across data (TP only),
        # optimizer moments FSDP-sharded. Kills the pathological
        # activation all-gathers XLA emits for FSDP weight gradients.
        env = ShardingEnv(mesh, rules=tuple(env.rules.items()), fsdp=False,
                          tp_fallback=tp_fallback)
        param_sh = M.param_shardings(cfg, env)

    t0 = time.time()
    with mesh, use_sharding(env):
        if shape.kind == "train":
            opt_abs = steps.abstract_opt_state(params_abs)
            opt_sh = steps.opt_shardings(
                M.param_shardings(cfg, opt_env) if zero1 else param_sh, env)
            jitted = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh))
            lowered = jitted.lower(params_abs, opt_abs, specs)
        elif shape.kind == "decode":
            # donate the KV/SSM cache: serving aliases it in place
            jitted = jax.jit(step, in_shardings=(param_sh, batch_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, specs)
        else:
            jitted = jax.jit(step, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(params_abs, specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    # newer jax returns a per-program list of dicts; older a single dict
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    # trip-count-aware per-device cost from the compiled HLO (XLA's
    # cost_analysis counts while bodies once — useless for scanned layers)
    hc = hlo_analyze(compiled.as_text())
    flops = hc.flops
    bytes_accessed = hc.traffic_bytes
    coll_bytes = hc.collective_bytes
    coll_ops = {k: {"count": int(v["count"]), "bytes": int(v["bytes"])}
                for k, v in hc.collective_ops.items()}
    terms = roofline_terms(flops, bytes_accessed, coll_bytes)

    n_params = cfg.num_params()
    n_active = cfg.num_active_params()
    # MODEL_FLOPS: 6*N*D for a train step (fwd+bwd), 2*N*D for inference
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    mult = 6 if shape.kind == "train" else 2
    model_flops_global = mult * n_active * tokens
    model_flops_per_dev = model_flops_global / n_chips

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "algo": algo.name,
        "fsdp": fsdp,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": _mem_dict(mem),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "collective_ops": coll_ops,
        "xla_cost_analysis_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "roofline": {k: (v if isinstance(v, str) else float(v))
                     for k, v in terms.items()},
        "n_params": n_params,
        "n_active_params": n_active,
        "model_flops_per_device": model_flops_per_dev,
        "useful_flops_ratio": (model_flops_per_dev / flops
                               if flops else None),
    }
    if verbose:
        mb = record["memory"].get("temp_size_in_bytes", 0) / 2**30
        arg_gb = record["memory"].get("argument_size_in_bytes", 0) / 2**30
        line = (f"[dryrun] {arch} x {shape_name} x {record['mesh']}: "
                f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
                f"args {arg_gb:.2f}GiB temp {mb:.2f}GiB | "
                f"flops/dev {flops:.3g} coll/dev {coll_bytes:.3g}B | "
                f"dominant={terms['dominant']}")
        if run_logger is not None:
            run_logger.print(line)
        else:
            print(line, flush=True)
    if run_logger is not None:
        run_logger.log_event(
            "dryrun", arch=arch, shape=shape_name, mesh=record["mesh"],
            shape_kind=shape.kind, lower_s=record["lower_s"],
            compile_s=record["compile_s"],
            temp_bytes=record["memory"].get("temp_size_in_bytes", 0),
            hlo_flops_per_device=flops,
            collective_bytes_per_device=coll_bytes,
            dominant=terms["dominant"])
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        tag = f"{arch}_{shape_name}_{record['mesh']}"
        if not fsdp:
            tag += "_nofsdp"
        tag += tag_suffix
        with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
            json.dump(record, f, indent=2)
    return record


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="architecture id")
    p.add_argument("--shape", default=None, choices=sorted(SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true",
                   help="run every assigned arch x shape")
    p.add_argument("--algo", default=None,
                   help="policy-optimization algorithm (registry name, "
                        "default a3po)")
    p.add_argument("--method", default=None,
                   help="DEPRECATED alias for --algo")
    p.add_argument("--no-fsdp", action="store_true")
    # §Perf optimization levers (see EXPERIMENTS.md §4)
    p.add_argument("--ep-moe", action="store_true",
                   help="expert-parallel shard_map MoE dispatch")
    p.add_argument("--kv-seq-shard", action="store_true",
                   help="shard decode KV cache along sequence")
    p.add_argument("--tp-fallback", action="store_true",
                   help="row-parallel fallback for non-divisible heads")
    p.add_argument("--hoist-gather", action="store_true",
                   help="hoist FSDP weight all-gather out of microbatches")
    p.add_argument("--tag", default="", help="suffix for result files")
    p.add_argument("--log-jsonl", default=None, metavar="FILE",
                   help="append one schema-versioned JSONL record per combo")
    p.add_argument("--quiet", action="store_true",
                   help="suppress stdout progress lines (JSONL still logs)")
    args = p.parse_args()
    if args.method:
        import warnings
        warnings.warn("--method is deprecated; use --algo",
                      DeprecationWarning)

    combos = []
    if args.all:
        for arch in list_archs(assigned_only=True):
            for shape in SHAPES:
                combos.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    log = RunLogger(args.log_jsonl, quiet=args.quiet)
    failures = []
    try:
        for arch, shape in combos:
            try:
                dryrun_one(arch, shape, multi_pod=args.multi_pod,
                           algo=args.algo or args.method or "a3po",
                           fsdp=not args.no_fsdp,
                           ep_moe=args.ep_moe,
                           kv_seq_shard=args.kv_seq_shard,
                           tp_fallback=args.tp_fallback,
                           hoist_gather=args.hoist_gather,
                           tag_suffix=args.tag, run_logger=log)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, repr(e)))
                log.log_event("dryrun_failure", arch=arch, shape=shape,
                              error=repr(e))
                traceback.print_exc()
        if failures:
            log.print(f"\nFAILED {len(failures)}/{len(combos)}:")
            for f in failures:
                log.print(f"   {f}")
            raise SystemExit(1)
        log.print(f"\nALL {len(combos)} combos compiled OK "
                  f"({'2x16x16' if args.multi_pod else '16x16'})")
    finally:
        log.close()


if __name__ == "__main__":
    main()
