"""Production and local meshes.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e mesh: 16x16 (one pod, 256 chips) or 2x16x16 (two pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1) -> Mesh:
    """Mesh over whatever devices exist (CPU tests: usually 1)."""
    devices = np.array(jax.devices())
    n = devices.size
    mp = model_parallel if n % model_parallel == 0 else 1
    return Mesh(devices.reshape(n // mp, mp), ("data", "model"))
