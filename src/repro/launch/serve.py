"""Production serving launcher: batched decode against a sharded cache.

On TPU this jits ``prefill_step``/``decode_step`` with the production mesh
shardings (see dryrun.py for the full-scale lowering); on CPU it serves a
reduced/toy config end-to-end.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch toy-2m --batch 8 \
      --max-new 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.models import model as M
from repro.rollout.engine import RolloutEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="toy-2m")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=8)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--waves", type=int, default=2)
    args = p.parse_args()

    cfg = get_config(args.arch)
    if jax.default_backend() == "cpu" and cfg.num_params() > 5e7:
        cfg = get_config(args.arch + "-reduced")
        print(f"(CPU host: serving reduced variant of {args.arch})")
    cfg = dataclasses.replace(cfg, dtype="float32")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = RolloutEngine(cfg, RLConfig(temperature=0.8),
                           max_new_tokens=args.max_new)
    rng = np.random.default_rng(0)
    for wave in range(args.waves):
        prompts = rng.integers(4, cfg.vocab_size,
                               (args.batch, args.prompt_len)).astype(np.int32)
        lengths = np.full((args.batch,), args.prompt_len, np.int32)
        t0 = time.perf_counter()
        rb = engine.generate(params, prompts, lengths,
                             jax.random.PRNGKey(wave))
        dt = time.perf_counter() - t0
        n = int(rb.gen_mask.sum())
        print(f"wave {wave}: {args.batch} seqs x {args.max_new} new -> "
              f"{n} tokens, {n/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
