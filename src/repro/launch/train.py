"""Production training launcher.

On a TPU slice this builds the production mesh, shards params/opt with the
logical rules, and drives async A-3PO training with the rollout engine on a
disjoint pod slice (weight publish = device_put across meshes). On CPU (this
container) it runs the same code path on a local mesh at toy scale — the
full-scale mesh program is exercised by ``dryrun.py``.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch toy-2m --steps 20 \
      --method loglinear [--mesh local|prod|prod-multipod]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.async_rl.orchestrator import simulate_async
from repro.data.tasks import ArithmeticTask
from repro.distributed.sharding import ShardingEnv, use_sharding
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.training.checkpoints import save_checkpoint


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="toy-2m")
    p.add_argument("--method", default="loglinear",
                   choices=["loglinear", "recompute", "sync"])
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--staleness", type=int, default=2)
    p.add_argument("--mesh", default="local",
                   choices=["local", "prod", "prod-multipod"])
    p.add_argument("--checkpoint", default=None)
    args = p.parse_args()

    if args.mesh == "local":
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-multipod")
    env = ShardingEnv(mesh)
    n_dev = int(np.prod(list(mesh.shape.values())))
    print(f"mesh {dict(mesh.shape)} ({n_dev} devices), arch {args.arch}, "
          f"method {args.method}")

    cfg = get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = dataclasses.replace(cfg, dtype="float32")
        if cfg.num_params() > 5e7:
            raise SystemExit(
                f"{args.arch} is full-scale ({cfg.num_params()/1e9:.0f}B "
                "params): use launch.dryrun on this host, or a TPU slice "
                "to actually train. Toy archs: toy-2m / toy-20m.")

    rl = RLConfig(group_size=4, num_minibatches=2, learning_rate=2e-4,
                  max_staleness=args.staleness + 1)
    task = ArithmeticTask(max_operand=9, n_terms=2, prompt_len=8)

    with mesh, use_sharding(env):
        state, recs = simulate_async(
            cfg, rl, task, args.method, args.steps, n_prompts=8,
            max_new_tokens=6,
            staleness=0 if args.method == "sync" else args.staleness)
    for r in recs[:: max(1, len(recs) // 8)]:
        print(f"  step {r.step:3d} reward {r.reward:.3f} loss {r.loss:+.4f} "
              f"prox {r.prox_time_s*1e3:.2f}ms stale {r.staleness_mean:.1f}")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, {"params": state.params},
                        {"arch": args.arch, "method": args.method,
                         "steps": args.steps})
        print("saved", args.checkpoint)


if __name__ == "__main__":
    main()
