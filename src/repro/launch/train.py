"""Production training launcher.

On a TPU slice this builds the production mesh, shards params/opt with the
logical rules, and drives async A-3PO training with the rollout engine on a
disjoint pod slice (weight publish = device_put across meshes). On CPU (this
container) ``--mesh local`` runs the same code path on a local mesh at toy
scale, and ``--mesh prod``/``prod-multipod`` dry-runs the compiled training
engine against the full-scale mesh: params and Adam moments are placed with
``ShardingEnv``'s logical-axis rules, the scan-based ``train_step`` is
lowered + compiled with those in_shardings, and the launcher verifies no
weight matrix is left fully replicated.

Algorithm selection goes through the Algorithm registry
(``core.algorithms``): ``--algo a3po|recompute|sync|asympo|grpo_mu|...``
(``--algo list`` enumerates it, including third-party registrations).

Observability (``repro.obs``): ``--trace trace.json`` records spans for
rollout, prefill, decode horizons, weight publishes, prox passes, and
train steps (Chrome/Perfetto-loadable, publish->resume flow events
included) and brackets the compiled hot paths with
``jax.profiler.TraceAnnotation``; ``--log-jsonl run.jsonl`` writes one
schema-versioned record per step; ``--quiet`` suppresses the human
stdout lines; ``--metrics-prom FILE`` dumps the metrics registry in
prometheus text format at exit. ``--engine async`` drives the real
thread-decoupled orchestrator through the serving control plane
(continuous batching + fused decode horizons) instead of the
deterministic simulator. Render a run summary afterwards with
``python -m repro.obs.report --jsonl run.jsonl --trace trace.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch toy-2m --steps 20 \
      --algo a3po [--mesh local|prod|prod-multipod] \
      [--trace trace.json] [--log-jsonl run.jsonl] [--quiet] \
      [--engine sim|async]
  PYTHONPATH=src python -m repro.launch.train --algo list
"""
from __future__ import annotations

import os
import sys

# The production meshes need 256/512 placeholder host devices; XLA_FLAGS
# must be set before the first jax import (same trick as launch/dryrun.py).
if __name__ == "__main__" and any(
        a in ("prod", "prod-multipod") or a.startswith("--mesh=prod")
        for a in sys.argv):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import functools  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import RLConfig  # noqa: E402
from repro.configs.registry import get_config  # noqa: E402
from repro.core.algorithms import (  # noqa: E402
    Algorithm,
    registry_table,
    resolve_algorithm,
)
from repro.async_rl.orchestrator import simulate_async  # noqa: E402
from repro.data.tasks import ArithmeticTask  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    ShardingEnv,
    use_sharding,
)
from repro.launch.mesh import make_local_mesh, make_production_mesh  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.obs.metrics import get_registry  # noqa: E402
from repro.obs.runlog import RunLogger  # noqa: E402
from repro.obs.tracing import SpanTracer, install_tracer  # noqa: E402
from repro.training import trainer as trainer_mod  # noqa: E402
from repro.training.checkpoints import save_checkpoint  # noqa: E402


def _replicated_weights(sh_tree, abs_tree) -> list:
    """Paths of >=2-D tensors whose sharding spec is fully replicated."""
    flat_sh, _ = jax.tree_util.tree_flatten_with_path(sh_tree)
    flat_abs = jax.tree.leaves(abs_tree)
    bad = []
    for (path, sh), leaf in zip(flat_sh, flat_abs):
        if len(leaf.shape) >= 2 and all(p is None for p in sh.spec):
            bad.append(jax.tree_util.keystr(path))
    return bad


def sharded_dryrun(cfg, rl: RLConfig, env: ShardingEnv, algo: Algorithm,
                   batch_size: int = 32, seq_len: int = 14,
                   num_microbatches: int = 1) -> None:
    """Lower + compile the scan-based training engine on the production
    mesh with ShardingEnv placements for params, Adam moments, and batch."""
    params_abs = M.abstract_params(cfg, dtype=jnp.dtype(cfg.dtype))
    param_sh = M.param_shardings(cfg, env)
    opt_abs = steps.abstract_opt_state(params_abs)
    opt_sh = steps.opt_shardings(param_sh, env)

    bad = _replicated_weights(param_sh, params_abs)
    assert not bad, f"fully-replicated weight tensors on the mesh: {bad}"
    bad_m = _replicated_weights(opt_sh["m"], params_abs)
    assert not bad_m, f"fully-replicated Adam moments on the mesh: {bad_m}"
    print(f"[sharded] params + Adam moments carry ShardingEnv placements "
          f"({len(jax.tree.leaves(param_sh))} tensors, 0 replicated "
          f"weight matrices)")

    B, T = batch_size, seq_len
    i32, f32 = jnp.int32, jnp.float32
    batch_abs = dict(
        version=jax.ShapeDtypeStruct((), i32),
        tokens=jax.ShapeDtypeStruct((B, T), i32),
        behav_logp=jax.ShapeDtypeStruct((B, T - 1), f32),
        mask=jax.ShapeDtypeStruct((B, T - 1), f32),
        versions=jax.ShapeDtypeStruct((B,), i32),
        rewards=jax.ShapeDtypeStruct((B,), f32),
    )
    batch_sh = dict(
        version=env.sharding((), ()),
        tokens=env.sharding((B, T), ("batch", None)),
        behav_logp=env.sharding((B, T - 1), ("batch", None)),
        mask=env.sharding((B, T - 1), ("batch", None)),
        versions=env.sharding((B,), ("batch",)),
        rewards=env.sharding((B,), ("batch",)),
    )

    step = functools.partial(
        trainer_mod._train_step_impl, cfg=cfg, rl=rl, algo=algo,
        num_minibatches=rl.num_minibatches,
        num_microbatches=num_microbatches)

    def wrapped(params, opt, batch):
        # the dry-run has no real recomputed prox; stand in with behav_logp
        # (same shape/sharding) so the compiled program is representative
        prox = batch["behav_logp"] if algo.needs_prox_forward else None
        return step(params, opt, batch["version"], batch["tokens"],
                    batch["behav_logp"], batch["mask"], batch["versions"],
                    batch["rewards"], prox)

    t0 = time.time()
    with env.mesh, use_sharding(env):
        jitted = jax.jit(wrapped, in_shardings=(param_sh, opt_sh, batch_sh),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    out_p_sh, _, _ = compiled.output_shardings
    bad_out = [p for (p, sh), leaf in
               zip(jax.tree_util.tree_flatten_with_path(out_p_sh)[0],
                   jax.tree.leaves(params_abs))
               if len(leaf.shape) >= 2 and sh.is_fully_replicated]
    assert not bad_out, f"compiled step replicates weights: {bad_out}"
    mem = compiled.memory_analysis()
    print(f"[sharded] train_step lower {t_lower:.1f}s compile "
          f"{t_compile:.1f}s | args "
          f"{mem.argument_size_in_bytes / 2**20:.1f}MiB temp "
          f"{mem.temp_size_in_bytes / 2**20:.1f}MiB | output params stay "
          f"sharded")


def print_algo_list() -> None:
    """``--algo list``: enumerate the Algorithm registry with flags."""
    cols = ("needs_behav_logp", "needs_prox_forward", "needs_versions",
            "needs_group_rewards", "on_policy")
    header = f"{'name':10s} {'aliases':10s} " \
        + " ".join(f"{c:>{len(c)}s}" for c in cols)
    print(header)
    print("-" * len(header))
    for r in registry_table():
        alias = ",".join(r["aliases"]) or "-"
        flags = " ".join(f"{'yes' if r[c] else 'no':>{len(c)}s}"
                         for c in cols)
        print(f"{r['name']:10s} {alias:10s} {flags}  # {r['doc']}")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="toy-2m")
    p.add_argument("--algo", default=None,
                   help="policy-optimization algorithm (registry name, "
                        "default a3po), or 'list' to enumerate the "
                        "registry")
    p.add_argument("--method", default=None,
                   help="DEPRECATED alias for --algo")
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--staleness", type=int, default=2)
    p.add_argument("--mesh", default="local",
                   choices=["local", "prod", "prod-multipod"])
    p.add_argument("--microbatch", type=int, default=1,
                   help="gradient-accumulation microbatches per minibatch")
    p.add_argument("--checkpoint", default=None)
    p.add_argument("--engine", default="sim", choices=["sim", "async"],
                   help="sim: deterministic single-thread simulation; "
                        "async: thread-decoupled orchestrator through the "
                        "serving control plane (continuous batching, "
                        "fused decode horizons, interruptible publishes)")
    p.add_argument("--trace", default=None, metavar="FILE",
                   help="record spans and export a Chrome/Perfetto "
                        "trace.json here")
    p.add_argument("--log-jsonl", default=None, metavar="FILE",
                   help="write one schema-versioned JSONL record per "
                        "training step")
    p.add_argument("--quiet", action="store_true",
                   help="suppress human status lines (JSONL/trace still "
                        "written)")
    p.add_argument("--metrics-prom", default=None, metavar="FILE",
                   help="dump the metrics registry (serving + training) "
                        "in prometheus text format at exit")
    # fault tolerance (repro.resilience)
    p.add_argument("--ckpt-dir", default=None, metavar="DIR",
                   help="crash-consistent step-named checkpoints go here "
                        "(atomic npz+json pairs with checksum + a 'latest' "
                        "pointer)")
    p.add_argument("--ckpt-every", type=int, default=0, metavar="N",
                   help="commit a checkpoint every N completed steps "
                        "(requires --ckpt-dir)")
    p.add_argument("--resume", default=None, metavar="auto|STEP",
                   help="'auto': resume from the newest valid checkpoint "
                        "in --ckpt-dir (fresh start when none); an "
                        "integer: resume from exactly that step's "
                        "checkpoint. Sim-engine resume is bit-identical "
                        "to the uninterrupted run.")
    p.add_argument("--fault", action="append", default=[],
                   metavar="KIND@AT[xN][:MAG]",
                   help="inject a deterministic fault (repeatable), e.g. "
                        "rollout_crash@1, train_crash@3, publish_fail@0x2, "
                        "queue_stall@2:0.5, nan_grad@4, kv_exhaust@5x3:64, "
                        "nan_logits@2")
    p.add_argument("--fault-seed", type=int, default=0,
                   help="seed for the fault plane's RNG (which row/reward "
                        "gets poisoned, backoff jitter)")
    p.add_argument("--guard", default="off",
                   choices=["off", "skip", "rollback"],
                   help="non-finite update policy: 'skip' keeps the "
                        "previous params/opt for poisoned minibatches "
                        "(on-device, no extra host sync); 'rollback' also "
                        "restores the last checkpoint when a step goes "
                        "non-finite or diverges")
    args = p.parse_args()

    if args.algo == "list":
        print_algo_list()
        return
    if args.method:
        import warnings
        warnings.warn("--method is deprecated; use --algo",
                      DeprecationWarning)
    # an explicit --algo always wins over the deprecated --method alias
    algo = resolve_algorithm(args.algo or args.method or "a3po")

    log = RunLogger(args.log_jsonl, quiet=args.quiet)
    tracer = (install_tracer(SpanTracer(), annotate_jax=True)
              if args.trace else None)

    if args.mesh == "local":
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-multipod")
    env = ShardingEnv(mesh)
    n_dev = int(np.prod(list(mesh.shape.values())))
    log.print(f"mesh {dict(mesh.shape)} ({n_dev} devices), "
              f"arch {args.arch}, algo {algo.name}")
    log.log_event("meta", mesh=args.mesh, n_devices=n_dev, arch=args.arch,
                  algo=algo.name, steps=args.steps, engine=args.engine,
                  staleness=args.staleness)

    cfg = get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = dataclasses.replace(cfg, dtype="float32")

    rl = RLConfig(group_size=4, num_minibatches=2, learning_rate=2e-4,
                  max_staleness=args.staleness + 1)

    if args.mesh != "local" and jax.default_backend() == "cpu":
        # full-scale mesh on the host platform: dry-run the compiled,
        # sharded engine instead of stepping 256 emulated devices
        sharded_dryrun(cfg, rl, env, algo,
                       num_microbatches=args.microbatch)
        if tracer is not None:
            install_tracer(None)
            tracer.export(args.trace)
        log.close()
        return

    if jax.default_backend() == "cpu" and cfg.num_params() > 5e7:
        raise SystemExit(
            f"{args.arch} is full-scale ({cfg.num_params()/1e9:.0f}B "
            "params): use launch.dryrun or --mesh prod on this host, or a "
            "TPU slice to actually train. Toy archs: toy-2m / toy-20m.")

    task = ArithmeticTask(max_operand=9, n_terms=2, prompt_len=8)

    # --- fault tolerance: checkpoints, guards, fault plane, resume -------
    resilience = None
    resume = None
    if args.ckpt_dir or args.fault or args.guard != "off":
        from repro.resilience import (CheckpointManager, FaultPlan,
                                      ResilienceConfig, TrainGuard)
        resilience = ResilienceConfig(
            faults=(FaultPlan.from_strings(args.fault, seed=args.fault_seed)
                    if args.fault else None),
            guard=(TrainGuard(policy=args.guard) if args.guard != "off"
                   else None),
            checkpointer=(CheckpointManager(args.ckpt_dir)
                          if args.ckpt_dir else None),
            ckpt_every=args.ckpt_every, seed=args.fault_seed)
    if args.resume:
        if not args.ckpt_dir:
            raise SystemExit("--resume requires --ckpt-dir")
        ckpt = resilience.checkpointer
        if args.resume == "auto":
            resume = ckpt.restore_latest()
        else:
            resume = ckpt.restore(ckpt.path_for(int(args.resume)))
        if resume is not None:
            log.print(f"resuming at step {resume.step} "
                      f"(version {int(resume.state.version)}) from "
                      f"{resume.path}")
            log.log_event("resume", step=resume.step, path=resume.path)
        else:
            log.print(f"--resume auto: no valid checkpoint in "
                      f"{args.ckpt_dir}; starting fresh")

    with mesh, use_sharding(env):
        if args.engine == "async":
            from repro.async_rl.orchestrator import AsyncOrchestrator
            from repro.training.trainer import Trainer
            orch = AsyncOrchestrator(
                cfg, rl, task, algo, n_prompts=8, max_new_tokens=6,
                use_control_plane=True, resilience=resilience)
            start_step = 0
            if resume is not None:
                state = resume.state
                start_step = resume.step
                if resume.task_rng_state is not None:
                    task.rng.bit_generator.state = resume.task_rng_state
            else:
                state = Trainer(cfg, rl, algo).init_state(
                    jax.random.PRNGKey(7))
            state, recs = orch.run(state, args.steps, run_logger=log,
                                   start_step=start_step)
        else:
            state, recs = simulate_async(
                cfg, rl, task, algo, args.steps, n_prompts=8,
                max_new_tokens=6,
                staleness=0 if algo.on_policy else args.staleness,
                num_microbatches=args.microbatch, run_logger=log,
                resilience=resilience, resume=resume)
    for r in recs[:: max(1, len(recs) // 8)]:
        log.print(
            f"  step {r.step:3d} reward {r.reward:.3f} loss {r.loss:+.4f} "
            f"prox {r.prox_time_s*1e3:.2f}ms stale {r.staleness_mean:.1f} "
            f"tok/s {r.train_tokens / max(r.train_time_s, 1e-9):.0f} "
            f"syncs {r.host_syncs:.0f}")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, {"params": state.params},
                        {"arch": args.arch, "algo": algo.name,
                         "steps": args.steps})
        log.print(f"saved {args.checkpoint}")
        log.log_event("checkpoint", path=args.checkpoint)
    if tracer is not None:
        install_tracer(None)
        tracer.export(args.trace)
        log.print(f"trace -> {args.trace}")
    if args.metrics_prom:
        get_registry().dump_prometheus(args.metrics_prom)
        log.print(f"prometheus metrics -> {args.metrics_prom}")
    log.close()


if __name__ == "__main__":
    main()
