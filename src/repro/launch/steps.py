"""Jit-able production step functions + abstract input specs.

These are the programs the dry-run lowers for every (arch x shape x mesh)
combination and that ``launch/train.py`` / ``launch/serve.py`` execute:

* ``train_step``   — full A-3PO RL update: score + decoupled loss + bwd + Adam
* ``prefill_step`` — prompt ingestion, returns last-token logits + kv cache
* ``decode_step``  — one token for every sequence against a full cache

All steps take a single ``batch`` dict whose abstract structure comes from
``input_specs`` (ShapeDtypeStructs — no allocation) so in_shardings line up
1:1 with the spec tree.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig, RLConfig
from repro.core.algorithms import LossInputs, resolve_algorithm
from repro.distributed.sharding import ShardingEnv, current_env
from repro.kernels.logprob import token_logprob_entropy
from repro.models import model as M
from repro.models.layers import logits_from_hidden, output_head_weight
from repro.models.params import shardings_from_specs
from repro.training.optimizer import adam_update


def decode_window(cfg: ModelConfig, shape: InputShape) -> Optional[int]:
    """Sliding-window policy at the long-context decode point.

    SSM/hybrid state is O(1); MLA's latent cache is compact enough to keep
    the full 500k context. Full-attention archs use the documented
    sliding-window variant (DESIGN.md §4)."""
    if shape.name != "long_500k":
        return None
    if cfg.arch_type in ("ssm", "hybrid"):
        return None
    if cfg.mla is not None:
        return None
    return cfg.long_context_window


# ----------------------------------------------------------------- factories
def _hoisted_gather(params, cfg: ModelConfig):
    """FSDP all-gather hoisting (§Perf lever): constrain a compute copy of
    the weights to their non-FSDP sharding OUTSIDE the microbatch scan, so
    the data-axis all-gather happens once per training step instead of per
    microbatch x fwd/bwd/remat. Gradients transpose back through the
    constraint as reduce-scatters onto the FSDP layout."""
    env = current_env()
    if env is None:
        return params
    gathered_env = ShardingEnv(env.mesh, rules=tuple(env.rules.items()),
                               fsdp=False)
    sh = shardings_from_specs(M.model_spec(cfg), gathered_env)
    return jax.tree.map(jax.lax.with_sharding_constraint, params, sh)


def make_train_step(cfg: ModelConfig, rl: RLConfig, algo="a3po",
                    current_version: int = 4, num_microbatches: int = 8,
                    hoist_fsdp_gather: bool = False):
    """Full RL training step over the global batch.

    ``algo`` is an ``Algorithm`` or registry name; its requires-flags
    decide which batch operands feed the loss (the dry-run stands in
    ``behav_logp`` for the recomputed prox — same shape/sharding).
    Gradient-accumulates over ``num_microbatches`` (lax.scan) — the paper
    bounds minibatches at 10,240 tokens; accumulation keeps activation
    memory at 1/num_microbatches of the global batch while the HLO stays
    O(1) in microbatch count."""
    algo = resolve_algorithm(algo, rl)
    F = cfg.frontend_tokens if cfg.frontend else 0

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        hidden, aux = M.forward_hidden(params, cfg, tokens[:, :-1],
                                       embeds=batch.get("embeds"))
        if F:
            hidden = hidden[:, F:]  # loss only over text positions
        w = output_head_weight(params["embedding"], cfg)
        logp, entropy = token_logprob_entropy(hidden, w, tokens[:, 1:])
        loss, metrics = algo.loss(logp, LossInputs(
            advantages=batch["advantages"], mask=batch["mask"],
            behav_logp=batch["behav_logp"], versions=batch["versions"],
            current_version=current_version,
            prox_logp=(batch["behav_logp"] if algo.needs_prox_forward
                       else None),
            entropy=entropy), rl)
        return loss + aux, metrics

    def train_step(params, opt, batch):
        B = batch["tokens"].shape[0]
        nm = num_microbatches if B % num_microbatches == 0 else 1
        compute_params = (_hoisted_gather(params, cfg)
                          if hoist_fsdp_gather else params)
        if nm == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(compute_params, batch)
            entropy = metrics["entropy"]
        else:
            mb = {k: v.reshape((nm, B // nm) + v.shape[1:])
                  for k, v in batch.items()}
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def accum(carry, micro):
                g_acc, loss_acc, ent_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(compute_params, micro)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, loss_acc + loss,
                        ent_acc + metrics["entropy"]), None

            (grads, loss, entropy), _ = jax.lax.scan(
                accum, (g0, jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / nm, grads)
            loss, entropy = loss / nm, entropy / nm
        params, opt, gnorm = adam_update(grads, opt, params, rl)
        return params, opt, loss, entropy, gnorm

    return train_step


def make_prefill_step(cfg: ModelConfig, shape: InputShape,
                      num_microbatches: int = 1):
    """Prefill the prompt batch; ``num_microbatches`` > 1 scans over batch
    chunks (prefill chunking — §Perf lever: activation temp scales with
    the live chunk while the produced KV cache is unchanged)."""
    window = decode_window(cfg, shape)

    def one(params, batch):
        hidden, cache = M.prefill(params, cfg, batch["tokens"],
                                  embeds=batch.get("embeds"), window=window)
        logits = logits_from_hidden(params["embedding"], hidden[:, -1:],
                                    cfg)[:, 0]
        return logits, cache

    if num_microbatches <= 1:
        return one

    def prefill_step(params, batch):
        B = batch["tokens"].shape[0]
        nm = num_microbatches if B % num_microbatches == 0 else 1
        if nm == 1:
            return one(params, batch)
        mb = {k: v.reshape((nm, B // nm) + v.shape[1:])
              for k, v in batch.items()}

        def body(_, micro):
            return None, one(params, micro)

        _, (logits, caches) = jax.lax.scan(body, None, mb)
        # un-chunk: [nm, B/nm, ...] -> [B, ...]; per-layer cache leaves are
        # [nm, L, B/nm, ...] -> [L, B, ...]
        logits = logits.reshape((B,) + logits.shape[2:])
        caches = jax.tree.map(
            lambda x: (jnp.moveaxis(x, 0, 1).reshape(
                (x.shape[1], B) + x.shape[3:])
                if x.ndim >= 3 else x.reshape((B,) + x.shape[2:])),
            caches)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, shape: InputShape):
    window = decode_window(cfg, shape)

    def decode_step(params, batch):
        return M.decode_step(params, cfg, batch["cache"], batch["tokens"],
                             window=window)

    return decode_step


def make_step(cfg: ModelConfig, shape: InputShape, rl: RLConfig,
              algo="a3po"):
    if shape.kind == "train":
        return make_train_step(cfg, rl, algo)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, shape)
    return make_decode_step(cfg, shape)


# --------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: InputShape,
                rl: Optional[RLConfig] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this workload."""
    del rl
    B, S = shape.global_batch, shape.seq_len
    dtype = jnp.dtype(cfg.dtype)
    i32, f32 = jnp.int32, jnp.float32
    F = cfg.frontend_tokens if cfg.frontend else 0
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        # total context = F frontend embeddings + (S - F) text tokens
        Tt = S - F
        specs["tokens"] = jax.ShapeDtypeStruct((B, Tt), i32)
        specs["behav_logp"] = jax.ShapeDtypeStruct((B, Tt - 1), f32)
        specs["advantages"] = jax.ShapeDtypeStruct((B, Tt - 1), f32)
        specs["mask"] = jax.ShapeDtypeStruct((B, Tt - 1), f32)
        specs["versions"] = jax.ShapeDtypeStruct((B,), i32)
        if F:
            specs["embeds"] = jax.ShapeDtypeStruct((B, F, cfg.d_model),
                                                   dtype)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S - F), i32)
        if F:
            specs["embeds"] = jax.ShapeDtypeStruct((B, F, cfg.d_model),
                                                   dtype)
    elif shape.kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((B,), i32)
        window = decode_window(cfg, shape)
        specs["cache"] = M.init_cache(cfg, B, S, abstract=True,
                                      window=window)
    else:
        raise ValueError(shape.kind)
    return specs


def abstract_opt_state(params_abstract):
    """Abstract Adam state matching ``training.optimizer.adam_init``."""
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(f32, params_abstract),
        "v": jax.tree.map(f32, params_abstract),
        "t": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_shardings(param_sh, env: ShardingEnv):
    return {
        "m": param_sh,
        "v": param_sh,
        "t": env.sharding((), ()),
    }


def batch_shardings(cfg: ModelConfig, shape: InputShape, env: ShardingEnv,
                    specs: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for name, spec in specs.items():
        if name == "cache":
            out["cache"] = M.cache_shardings(cfg, env, spec)
        elif name == "embeds":
            out[name] = env.sharding(spec.shape, ("batch", None, "act_embed"))
        elif spec.ndim == 1:
            out[name] = env.sharding(spec.shape, ("batch",))
        else:
            logical = ("batch",) + (None,) * (spec.ndim - 1)
            out[name] = env.sharding(spec.shape, logical)
    return out
