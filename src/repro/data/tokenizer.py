"""Character-level tokenizer for the synthetic math tasks.

GSM8K / DAPO-Math-17k are unavailable offline; the toy task family uses a
small closed vocabulary so end-to-end RL runs on CPU. IDs 0-3 are special.
"""
from __future__ import annotations

from typing import List

PAD, BOS, EOS, SEP = 0, 1, 2, 3
_CHARS = "0123456789+-*=() ."
CHAR_TO_ID = {c: i + 4 for i, c in enumerate(_CHARS)}
ID_TO_CHAR = {i: c for c, i in CHAR_TO_ID.items()}
VOCAB_SIZE = 4 + len(_CHARS)  # 22 (toy model vocab 64 leaves headroom)


def encode(text: str, add_bos: bool = False) -> List[int]:
    ids = [BOS] if add_bos else []
    ids.extend(CHAR_TO_ID[c] for c in text)
    return ids


def decode(ids, stop_at_eos: bool = True) -> str:
    out = []
    for i in ids:
        i = int(i)
        if i == EOS and stop_at_eos:
            break
        if i in (PAD, BOS, SEP):
            continue
        out.append(ID_TO_CHAR.get(i, "?"))
    return "".join(out)
