"""Synthetic verifiable math tasks (the offline GSM8K stand-in).

Each task yields (prompt, verifier). Rewards are binary exact-match like the
paper's math verifiers; prompts are uniform-length (right padding inside the
prompt region) so batched generation is rectangular.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.data import tokenizer as tok


@dataclasses.dataclass
class TaskBatch:
    prompts: np.ndarray        # [B, P] int32, right-padded with PAD
    prompt_lengths: np.ndarray  # [B]
    answers: List[str]


class ArithmeticTask:
    """Multi-step addition/subtraction chains, e.g. '12+34-5=' -> '41'."""

    def __init__(self, max_operand: int = 99, n_terms: int = 2,
                 prompt_len: int = 16, max_answer_len: int = 6,
                 seed: int = 0):
        self.max_operand = max_operand
        self.n_terms = n_terms
        self.prompt_len = prompt_len
        self.max_answer_len = max_answer_len
        self.rng = np.random.default_rng(seed)

    def sample(self, n: int) -> TaskBatch:
        prompts = np.full((n, self.prompt_len), tok.PAD, np.int32)
        lengths = np.zeros((n,), np.int32)
        answers: List[str] = []
        for i in range(n):
            terms = self.rng.integers(0, self.max_operand + 1,
                                      size=self.n_terms)
            ops = self.rng.choice(["+", "-"], size=self.n_terms - 1)
            expr = str(terms[0])
            val = int(terms[0])
            for t, op in zip(terms[1:], ops):
                expr += op + str(t)
                val = val + int(t) if op == "+" else val - int(t)
            text = expr + "="
            ids = tok.encode(text, add_bos=True)
            assert len(ids) <= self.prompt_len, (text, self.prompt_len)
            prompts[i, : len(ids)] = ids
            lengths[i] = len(ids)
            answers.append(str(val))
        return TaskBatch(prompts, lengths, answers)

    def reward(self, completion_ids, answer: str) -> float:
        return 1.0 if tok.decode(completion_ids) == answer else 0.0

    def rewards(self, completions: np.ndarray, answers: List[str]
                ) -> np.ndarray:
        return np.array([self.reward(c, a)
                         for c, a in zip(completions, answers)], np.float32)

    # ------------------------------------------------------------ SFT warmup
    def sft_batch(self, n: int, total_len: int):
        """Supervised sequences 'a+b=c<EOS>' for base-policy warmup.

        Returns (tokens [n, total_len], loss_mask [n, total_len-1]) where the
        mask covers answer tokens only (mirrors instruct-tuning a base model
        before RL, as the paper's setups assume).
        """
        batch = self.sample(n)
        tokens = np.full((n, total_len), tok.PAD, np.int32)
        mask = np.zeros((n, total_len - 1), np.float32)
        for i in range(n):
            p = batch.prompts[i, : batch.prompt_lengths[i]]
            ans = tok.encode(batch.answers[i]) + [tok.EOS]
            seq = list(p) + ans
            seq = seq[:total_len]
            tokens[i, : len(seq)] = seq
            lo = int(batch.prompt_lengths[i]) - 1  # predict first answer tok
            hi = min(len(seq) - 1, total_len - 1)
            mask[i, lo:hi] = 1.0
        return tokens, mask
