from repro.data.tasks import ArithmeticTask, TaskBatch  # noqa: F401
from repro.data import tokenizer  # noqa: F401
