"""Mamba2 (SSD) block: chunked matmul-form scan (TPU-native) + O(1) decode.

The GPU reference implements SSD with a fused selective-scan CUDA kernel.
On TPU we use the *state-space duality* chunked form instead: intra-chunk
interactions are chunk x chunk matmuls (MXU-friendly), and only the short
inter-chunk recurrence runs as a ``lax.scan`` over ``S / chunk_size`` steps.
The per-chunk matmuls are also provided as a Pallas kernel
(``repro.kernels.ssd``); this module is the pure-jnp system path and the
kernel's oracle.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.distributed.sharding import constrain
from repro.kernels.ssd.ops import ssd_decode_step
from repro.models.params import ParamSpec


def ssm_spec(cfg: ModelConfig) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    nh = s.num_heads(d)
    conv_dim = din + 2 * s.d_state
    return {
        "in_proj": ParamSpec((d, 2 * din + 2 * s.d_state + nh),
                             ("embed", "ssm_inner")),
        "conv_w": ParamSpec((s.d_conv, conv_dim), ("conv", "ssm_inner"),
                            scale=s.d_conv ** -0.5),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((nh,), ("ssm_heads",), init="a_log"),
        "d_skip": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="dt_bias"),
        "norm": ParamSpec((din,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((din, d), ("ssm_inner", "embed")),
    }


def _split_proj(zxbcdt: jax.Array, s: SSMConfig, d_model: int):
    din = s.d_inner(d_model)
    nh = s.num_heads(d_model)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * s.d_state], axis=-1)
    del nh
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xbc [B,S,Cd]; w [K,Cd]."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    S = xbc.shape[1]
    out = sum(pad[:, i:i + S, :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float) -> jax.Array:
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(y.dtype)


def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                b: jax.Array, c: jax.Array, chunk: int,
                initial_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: [B,S,nh,hd]  (already conv'd/silu'd, head-split)
    dt: [B,S,nh]    (softplus'd)
    b, c: [B,S,ds]  (single group)
    Returns (y [B,S,nh,hd], final_state [B,nh,hd,ds]).
    """
    B, S, nh, hd = x.shape
    ds = b.shape[-1]
    if S % chunk != 0:
        chunk = S  # single chunk fallback (tiny test shapes)
    nc = S // chunk

    la = dt * (-jnp.exp(a_log.astype(jnp.float32)))  # [B,S,nh] log-decay
    xdt = x * dt[..., None].astype(x.dtype)

    def r(t, tail):  # reshape into chunks
        return t.reshape((B, nc, chunk) + tail)

    la_c = r(la, (nh,))
    x_c = r(xdt, (nh, hd))
    b_c = r(b, (ds,))
    c_c = r(c, (ds,))
    cum = jnp.cumsum(la_c, axis=2)  # [B,nc,cs,nh]

    # intra-chunk: Y[i] = sum_{j<=i} C_i.B_j * exp(cum_i - cum_j) * xdt_j
    cb = jnp.einsum("bnis,bnjs->bnij", c_c, b_c,
                    preferred_element_type=jnp.float32)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,i,j,nh]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    m = cb[..., None] * decay  # [B,nc,i,j,nh]
    y_intra = jnp.einsum("bnijh,bnjhd->bnihd", m.astype(x.dtype), x_c,
                         preferred_element_type=jnp.float32).astype(x.dtype)

    # per-chunk local end-state: S_n = sum_j exp(cum_last - cum_j) xdt_j b_j^T
    decay_last = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nc,cs,nh]
    s_local = jnp.einsum("bnjh,bnjhd,bnjs->bnhds",
                         decay_last.astype(x.dtype), x_c, b_c,
                         preferred_element_type=jnp.float32)

    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,nc,nh]
    if initial_state is None:
        initial_state = jnp.zeros((B, nh, hd, ds), jnp.float32)

    def step(state, inp):
        s_loc, cdec = inp  # [B,nh,hd,ds], [B,nh]
        prev = state
        new = prev * cdec[..., None, None] + s_loc
        return new, prev  # emit state *entering* the chunk

    final_state, prev_states = jax.lax.scan(
        step, initial_state.astype(jnp.float32),
        (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [B,nc,nh,hd,ds]

    # inter-chunk: Y[i] += exp(cum_i) * C_i . S_prev
    y_inter = jnp.einsum("bnis,bnhds->bnihd", c_c,
                         prev_states.astype(c_c.dtype),
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[..., None]
    y = y_intra + y_inter.astype(x.dtype)
    return y.reshape(B, S, nh, hd), final_state


def ssm_full(params, x: jax.Array, cfg: ModelConfig,
             initial_cache: Dict[str, Any] = None, pad_mask=None,
             valid_lens=None) -> Tuple[jax.Array, Dict[str, Any]]:
    """Full-sequence Mamba2 block. x: [B,S,d] -> (y, final cache).

    ``valid_lens`` [B] (requires ``initial_cache``): per-row count of real
    tokens under right padding; the returned conv tail is sliced at each
    row's true sequence end instead of the last K-1 rows, so ragged chunks
    resume exactly (a row with 0 valid tokens gets its old cache back
    bit-for-bit).
    """
    s = cfg.ssm
    d = cfg.d_model
    din, nh, hd = s.d_inner(d), s.num_heads(d), s.head_dim
    B, S, _ = x.shape

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    zxbcdt = constrain(zxbcdt, "batch", None, "ssm_inner")
    z, xbc_raw, dt = _split_proj(zxbcdt, s, d)

    init_state = None
    if initial_cache is not None:
        # prepend cached conv inputs for causal continuity
        xbc_raw = jnp.concatenate([initial_cache["conv"], xbc_raw], axis=1)
        init_state = initial_cache["state"]
        xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
        xbc = xbc[:, s.d_conv - 1:]
    else:
        xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs, b, c = jnp.split(xbc, [din, din + s.d_state], axis=-1)
    xh = xs.reshape(B, S, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    if pad_mask is not None:
        # padded steps must not advance the state: dt=0 => a=1, input gain=0.
        dt = dt * pad_mask[..., None].astype(dt.dtype)

    y, state = ssd_chunked(xh, dt, params["a_log"], b, c, s.chunk_size,
                           initial_state=init_state)
    y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, din)
    y = _gated_norm(y, z, params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    if valid_lens is not None:
        # ragged right-padding leaves pad inputs in the last K-1 rows;
        # slice each row's window at its true end. With the prepended
        # cache, xbc_raw[v : v + K-1] is exactly the window after
        # consuming v real tokens (v=0 returns the old cache unchanged).
        assert initial_cache is not None, "valid_lens requires initial_cache"
        conv_tail = jax.vmap(
            lambda row, off: jax.lax.dynamic_slice_in_dim(
                row, off, s.d_conv - 1, axis=0))(xbc_raw, valid_lens)
    else:
        conv_tail = xbc_raw[:, -(s.d_conv - 1):, :]
    return out, {"conv": conv_tail, "state": state}


def ssm_decode(params, x: jax.Array, cfg: ModelConfig,
               cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token recurrent step. x: [B,d]; cache {conv [B,K-1,Cd], state}."""
    s = cfg.ssm
    d = cfg.d_model
    din, nh, hd = s.d_inner(d), s.num_heads(d), s.head_dim
    B = x.shape[0]

    zxbcdt = jnp.einsum("bd,de->be", x, params["in_proj"])
    z, xbc_t, dt = _split_proj(zxbcdt, s, d)

    win = jnp.concatenate([cache["conv"], xbc_t[:, None]], axis=1)  # [B,K,Cd]
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", win, params["conv_w"]) + params["conv_b"])
    xs, b, c = jnp.split(conv_out, [din, din + s.d_state], axis=-1)
    xh = xs.reshape(B, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,nh]
    y, state = ssd_decode_step(cache["state"], xh, dt, params["a_log"], b, c)
    y = y.astype(x.dtype) + xh * params["d_skip"][None, :, None].astype(x.dtype)
    y = _gated_norm(y.reshape(B, din), z, params["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"])
    return out, {"conv": win[:, 1:], "state": state}


def init_ssm_cache(cfg: ModelConfig, batch: int, *, abstract: bool = False,
                   dtype=None) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    dtype = dtype or jnp.bfloat16
    conv_shape = (batch, s.d_conv - 1, s.d_inner(d) + 2 * s.d_state)
    state_shape = (batch, s.num_heads(d), s.head_dim, s.d_state)
    if abstract:
        return {"conv": jax.ShapeDtypeStruct(conv_shape, dtype),
                "state": jax.ShapeDtypeStruct(state_shape, jnp.float32)}
    return {"conv": jnp.zeros(conv_shape, dtype),
            "state": jnp.zeros(state_shape, jnp.float32)}


SSM_CACHE_LOGICAL = {"conv": ("batch", None, "ssm_inner"),
                     "state": ("batch", "ssm_heads", None, None)}
