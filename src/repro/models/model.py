"""Composable decoder LM over the assigned architecture families.

Layers are *stacked and scanned* (``jax.lax.scan``) so the HLO is O(1) in
depth — required to compile 64–88 layer models against a 512-device host
mesh in tolerable time. Hybrid (Zamba2) models scan over periods of
(attn_every-1) SSM blocks followed by one *shared* attention block.

Public entry points:
  model_spec / init_params / abstract_params / param_shardings
  forward_hidden / forward_logits                  (train + prefill)
  init_cache / cache_shardings / decode_step       (serving)
  prefill                                          (populate a decode cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingEnv, constrain
from repro.models import blocks
from repro.models.layers import (
    embed_tokens,
    embedding_spec,
    logits_from_hidden,
    rmsnorm,
    rmsnorm_spec,
)
from repro.models import ssm as ssm_mod
from repro.models.params import (
    ParamSpec,
    SpecTree,
    abstract_from_specs,
    init_from_specs,
    shardings_from_specs,
    stack_specs,
)


# ----------------------------------------------------------------- structure
def _layout(cfg: ModelConfig):
    """(n_attn, n_ssm, n_periods, per_period_ssm, tail_ssm)."""
    kinds = cfg.block_kinds()
    n_attn = sum(1 for k in kinds if k == "attn")
    n_ssm = len(kinds) - n_attn
    if cfg.arch_type == "hybrid":
        assert cfg.share_attn_params, "hybrid wiring assumes shared attn"
        n_periods = cfg.num_layers // cfg.attn_every
        per = cfg.attn_every - 1
        tail = cfg.num_layers % cfg.attn_every
        assert n_periods * per + tail == n_ssm and n_periods == n_attn
        return n_attn, n_ssm, n_periods, per, tail
    return n_attn, n_ssm, 0, 0, 0


def model_spec(cfg: ModelConfig) -> SpecTree:
    spec: SpecTree = {
        "embedding": embedding_spec(cfg),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }
    if cfg.frontend is not None:
        # learned projector bias marks the modality boundary (frontend
        # embeddings themselves are provided precomputed per assignment)
        spec["frontend_proj"] = ParamSpec(
            (cfg.d_model, cfg.d_model), ("embed", "act_embed"),
            scale=cfg.d_model ** -0.5)
    n_attn, n_ssm, n_periods, per, tail = _layout(cfg)
    if cfg.arch_type == "hybrid":
        spec["ssm_blocks"] = stack_specs(blocks.ssm_block_spec(cfg), n_ssm)
        spec["shared_attn"] = blocks.attn_block_spec(cfg)
    elif cfg.arch_type == "ssm":
        spec["blocks"] = stack_specs(blocks.ssm_block_spec(cfg),
                                     cfg.num_layers)
    else:
        spec["blocks"] = stack_specs(blocks.attn_block_spec(cfg),
                                     cfg.num_layers)
    return spec


@functools.lru_cache(maxsize=64)
def _cached_spec(cfg: ModelConfig) -> SpecTree:
    """Memoized ``model_spec`` for read-only consumers (ModelConfig is a
    frozen/hashable dataclass). Callers must not mutate the returned tree."""
    return model_spec(cfg)


def init_params(cfg: ModelConfig, key: jax.Array, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    return init_from_specs(_cached_spec(cfg), key, dtype)


def abstract_params(cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    return abstract_from_specs(_cached_spec(cfg), dtype)


def param_shardings(cfg: ModelConfig, env: ShardingEnv):
    return shardings_from_specs(_cached_spec(cfg), env)


# ---------------------------------------------------------------- embeddings
def _embed_inputs(params, cfg: ModelConfig, tokens, embeds):
    x = embed_tokens(params["embedding"], tokens, cfg)
    if cfg.frontend is not None:
        assert embeds is not None, f"{cfg.name} needs frontend embeds"
        fe = jnp.einsum("bfd,de->bfe", embeds.astype(x.dtype),
                        params["frontend_proj"])
        x = jnp.concatenate([fe, x], axis=1)
    return constrain(x, "batch", None, "act_embed")


# ------------------------------------------------------------------ full seq
def forward_hidden(params, cfg: ModelConfig, tokens: jax.Array,
                   embeds: Optional[jax.Array] = None,
                   positions: Optional[jax.Array] = None,
                   pad_mask: Optional[jax.Array] = None,
                   window: Optional[int] = None,
                   ) -> Tuple[jax.Array, jax.Array]:
    """tokens [B,St] (+embeds [B,F,d]) -> (hidden [B,S,d], aux loss)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.arch_type == "hybrid":
        x, aux = _hybrid_full(params, cfg, x, positions, pad_mask, window)
    elif cfg.arch_type == "ssm":
        def body(carry, layer_params):
            h, aux = carry
            h, a, _ = blocks.ssm_block_full(layer_params, h, cfg, pad_mask)
            return (h, aux + a), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])
    else:
        def body(carry, layer_params):
            h, aux = carry
            # sequence-parallel region boundary: under the opt-in
            # ("seq_sp" -> "model") rule the residual stream (and hence
            # the remat-stored layer inputs) is seq-sharded between
            # blocks; GSPMD turns the TP all-reduces into
            # reduce-scatter/all-gather pairs around the attention/FFN
            # matmuls. Default rule is None => no-op.
            h = constrain(h, "batch", "seq_sp", "act_embed")
            h, a, _ = blocks.attn_block_full(layer_params, h, cfg, positions,
                                             pad_mask, window)
            return (h, aux + a), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"])

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def _hybrid_full(params, cfg, x, positions, pad_mask, window):
    n_attn, n_ssm, n_periods, per, tail = _layout(cfg)
    aux0 = jnp.zeros((), jnp.float32)
    main = jax.tree.map(
        lambda a: a[: n_periods * per].reshape((n_periods, per) + a.shape[1:]),
        params["ssm_blocks"])
    tail_p = jax.tree.map(lambda a: a[n_periods * per:], params["ssm_blocks"])

    def ssm_body(carry, layer_params):
        h, aux = carry
        h, a, _ = blocks.ssm_block_full(layer_params, h, cfg, pad_mask)
        return (h, aux + a), None

    def period_body(carry, period_params):
        h, aux = carry
        (h, aux), _ = jax.lax.scan(ssm_body, (h, aux), period_params)
        h, a, _ = blocks.attn_block_full(params["shared_attn"], h, cfg,
                                         positions, pad_mask, window)
        return (h, aux + a), None

    if cfg.remat:
        period_body = jax.checkpoint(period_body)
    (x, aux), _ = jax.lax.scan(period_body, (x, aux0), main)
    if tail:
        (x, aux), _ = jax.lax.scan(ssm_body, (x, aux), tail_p)
    return x, aux


def forward_logits(params, cfg: ModelConfig, tokens, embeds=None,
                   positions=None, pad_mask=None, window=None):
    h, aux = forward_hidden(params, cfg, tokens, embeds, positions,
                            pad_mask, window)
    return logits_from_hidden(params["embedding"], h, cfg), aux


# -------------------------------------------------------------------- caches
def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               abstract: bool = False, window: Optional[int] = None,
               dtype=None) -> Dict[str, Any]:
    """Stacked per-layer decode caches + per-seq lengths."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_attn, n_ssm, n_periods, per, tail = _layout(cfg)
    cache: Dict[str, Any] = {}

    def stack(tree, n):
        return jax.tree.map(
            lambda leaf: (jax.ShapeDtypeStruct((n,) + leaf.shape, leaf.dtype)
                          if abstract
                          else jnp.broadcast_to(leaf, (n,) + leaf.shape).copy()
                          ), tree)

    if n_attn:
        one = blocks.attn_cache_for(cfg, batch, max_len, abstract=abstract,
                                    window=window, dtype=dtype)
        cache["attn"] = stack(one, n_attn)
    if n_ssm:
        one = ssm_mod.init_ssm_cache(cfg, batch, abstract=abstract,
                                     dtype=dtype)
        cache["ssm"] = stack(one, n_ssm)
    cache["lengths"] = (jax.ShapeDtypeStruct((batch,), jnp.int32) if abstract
                        else jnp.zeros((batch,), jnp.int32))
    return cache


def cache_logical_axes(cfg: ModelConfig, cache: Dict[str, Any]):
    out: Dict[str, Any] = {}
    if "attn" in cache:
        log = blocks.attn_cache_logical(cfg)
        out["attn"] = {k: ("layers",) + v for k, v in log.items()}
    if "ssm" in cache:
        out["ssm"] = {k: ("layers",) + v
                      for k, v in ssm_mod.SSM_CACHE_LOGICAL.items()}
    out["lengths"] = ("batch",)
    return out


def cache_shardings(cfg: ModelConfig, env: ShardingEnv,
                    cache: Dict[str, Any]):
    logical = cache_logical_axes(cfg, cache)
    return jax.tree.map(
        lambda leaf, log: env.sharding(leaf.shape, log),
        cache, logical,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, jax.Array)))


# -------------------------------------------------------------------- decode
def decode_step(params, cfg: ModelConfig, cache: Dict[str, Any],
                tokens: jax.Array, window: Optional[int] = None,
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token for every sequence. tokens [B] -> (logits [B,V], cache)."""
    lengths = cache["lengths"]
    x = embed_tokens(params["embedding"], tokens[:, None], cfg)[:, 0]
    x = constrain(x, "batch", "act_embed")
    aux0 = jnp.zeros((), jnp.float32)
    new_cache = dict(cache)

    if cfg.arch_type == "hybrid":
        x = _hybrid_decode(params, cfg, x, new_cache, lengths, window)
    elif cfg.arch_type == "ssm":
        def body(carry, xs):
            h = carry
            layer_params, layer_cache = xs
            h, _, layer_cache = blocks.ssm_block_decode(layer_params, h, cfg,
                                                        layer_cache)
            return h, layer_cache
        x, ssm_cache = jax.lax.scan(body, x,
                                    (params["blocks"], cache["ssm"]))
        new_cache["ssm"] = ssm_cache
    else:
        def body(carry, xs):
            h = carry
            layer_params, layer_cache = xs
            h, _, layer_cache = blocks.attn_block_decode(
                layer_params, h, cfg, layer_cache, lengths, window)
            return h, layer_cache
        x, attn_cache = jax.lax.scan(body, x,
                                     (params["blocks"], cache["attn"]))
        new_cache["attn"] = attn_cache

    x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)[:, 0]
    logits = logits_from_hidden(params["embedding"], x, cfg)
    new_cache["lengths"] = lengths + 1
    del aux0
    return logits, new_cache


def _hybrid_decode(params, cfg, x, cache, lengths, window):
    n_attn, n_ssm, n_periods, per, tail = _layout(cfg)
    main_ssm_p = jax.tree.map(
        lambda a: a[: n_periods * per].reshape((n_periods, per) + a.shape[1:]),
        params["ssm_blocks"])
    tail_ssm_p = jax.tree.map(lambda a: a[n_periods * per:],
                              params["ssm_blocks"])
    main_ssm_c = jax.tree.map(
        lambda a: a[: n_periods * per].reshape((n_periods, per) + a.shape[1:]),
        cache["ssm"])
    tail_ssm_c = jax.tree.map(lambda a: a[n_periods * per:], cache["ssm"])

    def ssm_body(carry, xs):
        h = carry
        p, c = xs
        h, _, c = blocks.ssm_block_decode(p, h, cfg, c)
        return h, c

    def period_body(carry, xs):
        h = carry
        p_ssm, c_ssm, c_attn = xs
        h, c_ssm = jax.lax.scan(ssm_body, h, (p_ssm, c_ssm))
        h, _, c_attn = blocks.attn_block_decode(params["shared_attn"], h,
                                                cfg, c_attn, lengths, window)
        return h, (c_ssm, c_attn)

    x, (main_c, attn_c) = jax.lax.scan(
        period_body, x, (main_ssm_p, main_ssm_c, cache["attn"]))
    if tail:
        x, tail_c = jax.lax.scan(ssm_body, x, (tail_ssm_p, tail_ssm_c))
    else:
        tail_c = tail_ssm_c
    cache["ssm"] = jax.tree.map(
        lambda m, t: jnp.concatenate(
            [m.reshape((n_periods * per,) + m.shape[2:]), t], axis=0),
        main_c, tail_c)
    cache["attn"] = attn_c
    return x


# ------------------------------------------------------------------- prefill
def prefill(params, cfg: ModelConfig, tokens: jax.Array,
            embeds: Optional[jax.Array] = None,
            lengths: Optional[jax.Array] = None,
            max_len: Optional[int] = None,
            window: Optional[int] = None,
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the prompt, returning (hidden [B,S,d], populated decode cache).

    ``lengths`` are true per-seq prompt lengths (right padding); defaults to
    the full width.
    """
    x = _embed_inputs(params, cfg, tokens, embeds)
    B, S, _ = x.shape
    max_len = max_len or S
    if window is None and max_len < S:
        raise ValueError(
            f"decode cache max_len={max_len} < prompt length {S} "
            "(includes frontend tokens); only windowed caches may wrap")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if lengths is None:
        lengths = jnp.full((B,), tokens.shape[1], jnp.int32)
    if cfg.frontend is not None:
        lengths = lengths + cfg.frontend_tokens  # frontend prefix is valid
    pad_mask = jnp.arange(S)[None, :] < lengths[:, None]
    dtype = jnp.dtype(cfg.dtype)
    n_attn, n_ssm, n_periods, per, tail = _layout(cfg)
    L = min(max_len, window) if window else max_len

    def write_kv(kv):
        """kv: dict of [B,S,...] -> cache arrays [B,L,...]."""
        out = {}
        for name, arr in kv.items():
            buf_shape = (B, L) + arr.shape[2:]
            buf = jnp.zeros(buf_shape, dtype)
            if S <= L:
                buf = jax.lax.dynamic_update_slice(
                    buf, arr.astype(dtype), (0,) * arr.ndim)
            else:
                slots = jnp.arange(S - L, S) % L
                buf = buf.at[:, slots].set(arr[:, S - L:].astype(dtype))
            out[name] = buf
        return out

    aux0 = jnp.zeros((), jnp.float32)
    cache: Dict[str, Any] = {}
    if cfg.arch_type == "hybrid":
        x, attn_c, ssm_c = _hybrid_prefill(params, cfg, x, positions,
                                           pad_mask, window, write_kv)
        cache["attn"], cache["ssm"] = attn_c, ssm_c
    elif cfg.arch_type == "ssm":
        def body(carry, layer_params):
            h, aux = carry
            h, a, c = blocks.ssm_block_full(layer_params, h, cfg, pad_mask)
            return (h, aux + a), c
        (x, _), ssm_c = jax.lax.scan(body, (x, aux0), params["blocks"])
        cache["ssm"] = ssm_c
    else:
        def body(carry, layer_params):
            h, aux = carry
            h, a, kv = blocks.attn_block_full(layer_params, h, cfg,
                                              positions, pad_mask, window)
            if cfg.mla is not None:
                kv = write_kv({"ckv": kv[0], "krope": kv[1]})
            else:
                kv = write_kv({"k": kv[0], "v": kv[1]})
            return (h, aux + a), kv
        (x, _), attn_c = jax.lax.scan(body, (x, aux0), params["blocks"])
        cache["attn"] = attn_c

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    cache["lengths"] = lengths
    return x, cache


def _hybrid_prefill(params, cfg, x, positions, pad_mask, window, write_kv):
    n_attn, n_ssm, n_periods, per, tail = _layout(cfg)
    aux0 = jnp.zeros((), jnp.float32)
    main = jax.tree.map(
        lambda a: a[: n_periods * per].reshape((n_periods, per) + a.shape[1:]),
        params["ssm_blocks"])
    tail_p = jax.tree.map(lambda a: a[n_periods * per:],
                          params["ssm_blocks"])

    def ssm_body(carry, layer_params):
        h, aux = carry
        h, a, c = blocks.ssm_block_full(layer_params, h, cfg, pad_mask)
        return (h, aux + a), c

    def period_body(carry, period_params):
        h, aux = carry
        (h, aux), ssm_c = jax.lax.scan(ssm_body, (h, aux), period_params)
        h, a, kv = blocks.attn_block_full(params["shared_attn"], h, cfg,
                                          positions, pad_mask, window)
        return (h, aux + a), (ssm_c, write_kv({"k": kv[0], "v": kv[1]}))

    (x, aux), (main_ssm_c, attn_c) = jax.lax.scan(period_body, (x, aux0),
                                                  main)
    main_ssm_c = jax.tree.map(
        lambda a: a.reshape((n_periods * per,) + a.shape[2:]), main_ssm_c)
    if tail:
        (x, aux), tail_c = jax.lax.scan(ssm_body, (x, aux), tail_p)
        ssm_c = jax.tree.map(lambda m, t: jnp.concatenate([m, t], axis=0),
                             main_ssm_c, tail_c)
    else:
        ssm_c = main_ssm_c
    return x, attn_c, ssm_c
