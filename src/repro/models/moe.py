"""Top-k MoE with capacity-based dispatch (+ shared experts).

Two dispatch paths:

* ``moe_apply_gspmd`` — sort/scatter into a dense [E, C, d] buffer and let
  GSPMD reshard. Simple and correct, but XLA implements the cross-shard
  scatter as replicate+all-reduce of the WHOLE buffer (measured 21.6 TiB
  of all-reduce per step for qwen3-moe train_4k — see EXPERIMENTS.md
  §Perf).
* ``moe_apply_ep`` — explicit expert-parallel shard_map: tokens are
  seq-sharded over the "model" axis, routed pairs are bucketed by
  destination rank and exchanged with ``jax.lax.all_to_all``, experts
  compute locally, and a reverse all-to-all brings results home. This is
  the production TPU MoE pattern; collective traffic drops to the
  information-theoretic k-copies-of-tokens volume.

``moe_apply`` picks EP when the active ShardingEnv requests it and the
shapes allow (seq divisible by the model axis), else GSPMD.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed.sharding import constrain, current_env
from repro.models.layers import swiglu, swiglu_spec
from repro.models.params import ParamSpec


def moe_spec(cfg: ModelConfig) -> Dict[str, Any]:
    m = cfg.moe
    d = cfg.d_model
    spec: Dict[str, Any] = {
        "router": ParamSpec((d, m.num_experts), ("embed", "experts")),
        "w_gate": ParamSpec((m.num_experts, d, m.d_ff_expert),
                            ("experts", "embed", "expert_ff")),
        "w_up": ParamSpec((m.num_experts, d, m.d_ff_expert),
                          ("experts", "embed", "expert_ff")),
        "w_down": ParamSpec((m.num_experts, m.d_ff_expert, d),
                            ("experts", "expert_ff", "embed")),
    }
    if m.num_shared_experts > 0:
        spec["shared"] = swiglu_spec(d, m.num_shared_experts * m.d_ff_expert)
    return spec


def capacity(m: MoEConfig, num_tokens: int) -> int:
    c = int(math.ceil(m.top_k * num_tokens / m.num_experts
                      * m.capacity_factor))
    return max(c, m.top_k)


def route(router_w: jax.Array, x_flat: jax.Array, m: MoEConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (probs [T,E] f32, topk weights [T,k], topk idx [T,k])."""
    logits = jnp.einsum("td,de->te", x_flat, router_w,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return probs, top_w, top_i


def load_balance_loss(probs: jax.Array, top_i: jax.Array, m: MoEConfig
                      ) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    T = probs.shape[0]
    counts = jnp.zeros((m.num_experts,), jnp.float32).at[
        top_i.reshape(-1)].add(1.0)
    f = counts / (T * m.top_k)
    p = probs.mean(axis=0)
    return m.num_experts * jnp.sum(f * p)


def moe_apply(params, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y, aux_loss). Chooses EP shard_map vs GSPMD."""
    env = current_env()
    if (env is not None and getattr(env, "ep_shard_map", False)
            and "model" in env.mesh.axis_names):
        n_ranks = env.mesh.shape["model"]
        if cfg.moe.num_experts % n_ranks == 0 and x.shape[1] >= n_ranks:
            return moe_apply_ep(params, x, cfg, env)
    return moe_apply_gspmd(params, x, cfg)


def moe_apply_gspmd(params, x: jax.Array, cfg: ModelConfig
                    ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    probs, top_w, top_i = route(params["router"], xf, m)
    aux = load_balance_loss(probs, top_i, m) * m.router_aux_weight

    C = capacity(m, T)
    E = m.num_experts
    N = T * m.top_k
    flat_e = top_i.reshape(N)
    flat_w = top_w.reshape(N).astype(x.dtype)
    flat_t = jnp.repeat(jnp.arange(T), m.top_k)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(N) - starts[se]
    slot = jnp.where(pos < C, se * C + pos, E * C)  # overflow -> trash row

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xf[st])
    xe = buf[: E * C].reshape(E, C, d)
    xe = constrain(xe, "experts", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    h = constrain(h, "experts", None, None)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    ye = constrain(ye, "experts", None, None)

    padded = jnp.concatenate(
        [ye.reshape(E * C, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    y_pairs = padded[slot] * sw[:, None]
    y = jnp.zeros((T, d), x.dtype).at[st].add(y_pairs)

    if m.num_shared_experts > 0:
        y = y + swiglu(params["shared"], xf)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------- EP path
def _bucket_by(ids: jax.Array, values: jax.Array, n_buckets: int,
               cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sort (ids, values) into [n_buckets, cap, ...] with overflow drop.

    Returns (bucketed values, slot index per pair (== n_buckets*cap for
    dropped), sort order) so callers can route auxiliary arrays the same
    way and invert the permutation.
    """
    N = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    sid = ids[order]
    starts = jnp.searchsorted(sid, jnp.arange(n_buckets), side="left")
    pos = jnp.arange(N) - starts[sid]
    slot = jnp.where(pos < cap, sid * cap + pos, n_buckets * cap)
    buf = jnp.zeros((n_buckets * cap + 1,) + values.shape[1:],
                    values.dtype).at[slot].set(values[order])
    return buf[:-1].reshape((n_buckets, cap) + values.shape[1:]), slot, order


def _ep_body(x, router_w, w_gate, w_up, w_down, *, m: MoEConfig,
             n_ranks: int, all_axes):
    """Per-device expert-parallel MoE. x: [T_loc, d] (unique local tokens);
    w_*: this rank's expert slab [E/n_ranks, ...]."""
    T, d = x.shape
    e_per = m.num_experts // n_ranks
    k = m.top_k

    probs, top_w, top_i = route(router_w, x, m)
    aux = load_balance_loss(probs, top_i, m) * m.router_aux_weight
    aux = jax.lax.pmean(aux, all_axes)

    N = T * k
    flat_e = top_i.reshape(N)
    flat_w = top_w.reshape(N).astype(x.dtype)
    flat_t = jnp.repeat(jnp.arange(T), k)
    dest = flat_e // e_per

    # first-level bucket: destination rank, with the local-expert id (+1,
    # 0 marks padding) riding along in an int payload
    cap_send = max(int(math.ceil(N / n_ranks * m.capacity_factor)), k)
    send_x, slot, order = _bucket_by(dest, x[flat_t], n_ranks, cap_send)
    eid = ((flat_e % e_per) + 1).astype(jnp.int32)  # 0 == invalid
    send_e = jnp.zeros((n_ranks * cap_send + 1,), jnp.int32
                       ).at[slot].set(eid[order])
    send_e = send_e[:-1].reshape(n_ranks, cap_send)

    recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, "model", 0, 0, tiled=False)

    # second-level bucket: local expert (invalid slots -> trash bucket)
    Rn = n_ranks * cap_send
    rx = recv_x.reshape(Rn, d)
    re = jnp.where(recv_e.reshape(Rn) > 0, recv_e.reshape(Rn) - 1, e_per)
    C2 = max(int(math.ceil(Rn / e_per * m.capacity_factor)), 1)
    xe_full, slot2, order2 = _bucket_by(re, rx, e_per + 1, C2)
    xe = xe_full[:e_per]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) \
        * jnp.einsum("ecd,edf->ecf", xe, w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)

    # invert second-level bucketing back to the recv layout
    padded2 = jnp.concatenate(
        [ye.reshape(e_per * C2, d),
         jnp.zeros(((e_per + 1) * C2 - e_per * C2 + 1, d), ye.dtype)],
        axis=0)  # trash bucket and overflow row read zeros
    y_sorted = padded2[jnp.minimum(slot2, e_per * C2)]
    y_sorted = jnp.where((slot2 < e_per * C2)[:, None], y_sorted, 0.0)
    inv2 = jnp.argsort(order2, stable=True)
    ry = y_sorted[inv2].astype(x.dtype)  # [Rn, d], recv layout

    # reverse exchange back to the source ranks
    back = jax.lax.all_to_all(ry.reshape(n_ranks, cap_send, d),
                              "model", 0, 0, tiled=False)
    flat_back = jnp.concatenate(
        [back.reshape(n_ranks * cap_send, d),
         jnp.zeros((1, d), back.dtype)], axis=0)
    y_pairs_sorted = flat_back[slot]  # dropped pairs hit the zero row
    inv = jnp.argsort(order, stable=True)
    y_pairs = y_pairs_sorted[inv] * flat_w[:, None]
    y = jnp.zeros((T, d), x.dtype).at[flat_t].add(y_pairs)
    return y, aux


def moe_apply_ep(params, x: jax.Array, cfg: ModelConfig, env
                 ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map + all_to_all over 'model'."""
    from jax.experimental.shard_map import shard_map

    m = cfg.moe
    B, S, d = x.shape
    mesh = env.mesh
    n_ranks = mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # pad seq to a model-axis multiple (pad tokens only waste a sliver of
    # capacity; their outputs are sliced off below)
    orig_S = S
    S = -(-S // n_ranks) * n_ranks
    if S != orig_S:
        x = jnp.pad(x, ((0, 0), (0, S - orig_S), (0, 0)))

    all_axes = batch_axes + ("model",)

    def body(x_blk, router_w, w_gate, w_up, w_down):
        T = x_blk.shape[0] * x_blk.shape[1]
        y, aux = _ep_body(x_blk.reshape(T, d), router_w, w_gate, w_up,
                          w_down, m=m, n_ranks=n_ranks, all_axes=all_axes)
        return y.reshape(x_blk.shape), aux

    x_spec = P(batch_axes if batch_axes else None, "model", None)
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(), P("model"), P("model"), P("model")),
        out_specs=(x_spec, P()),
        check_rep=False)
    y, aux = mapped(x, params["router"], params["w_gate"], params["w_up"],
                    params["w_down"])
    if m.num_shared_experts > 0:
        y = y + swiglu(params["shared"], x.reshape(B * S, d)
                       ).reshape(B, S, d)
    if S != orig_S:
        y = y[:, :orig_S]
    return y, aux
