from repro.models.model import (  # noqa: F401
    abstract_params,
    cache_logical_axes,
    cache_shardings,
    decode_step,
    forward_hidden,
    forward_logits,
    init_cache,
    init_params,
    model_spec,
    param_shardings,
    prefill,
)
