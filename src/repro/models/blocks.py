"""Transformer / SSM block wiring (pre-norm residual, parallel, hybrid)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import rmsnorm, rmsnorm_spec, swiglu, swiglu_spec


# ------------------------------------------------------------------ specs
def attn_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    spec: Dict[str, Any] = {"ln1": rmsnorm_spec(d)}
    spec["attn"] = (mla_mod.mla_spec(cfg) if cfg.mla is not None
                    else attn_mod.attention_spec(cfg))
    if not cfg.parallel_block:
        spec["ln2"] = rmsnorm_spec(d)
    spec["ffn"] = (moe_mod.moe_spec(cfg) if cfg.moe is not None
                   else swiglu_spec(d, cfg.d_ff))
    return spec


def ssm_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln": rmsnorm_spec(cfg.d_model), "ssm": ssm_mod.ssm_spec(cfg)}


# ------------------------------------------------------------------ ffn glue
def _ffn(params, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe is not None:
        return moe_mod.moe_apply(params, x, cfg)
    return swiglu(params, x), jnp.zeros((), jnp.float32)


# ------------------------------------------------------------------ full seq
def attn_block_full(params, x, cfg: ModelConfig, positions, pad_mask=None,
                    window=None):
    """Returns (x, aux, kv) with kv the cacheables for prefill."""
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a_out, kv = mla_mod.mla_full(params["attn"], h, cfg, positions,
                                     pad_mask, window)
    else:
        a_out, kv = attn_mod.attention_full(params["attn"], h, cfg, positions,
                                            pad_mask, window)
    if cfg.parallel_block:
        f_out, aux = _ffn(params["ffn"], h, cfg)
        return x + a_out + f_out, aux, kv
    x = x + a_out
    h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
    f_out, aux = _ffn(params["ffn"], h2, cfg)
    return x + f_out, aux, kv


def ssm_block_full(params, x, cfg: ModelConfig, pad_mask=None,
                   initial_cache=None, valid_lens=None):
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    y, cache = ssm_mod.ssm_full(params["ssm"], h, cfg, initial_cache,
                                pad_mask=pad_mask, valid_lens=valid_lens)
    return x + y, jnp.zeros((), jnp.float32), cache


# ------------------------------------------------------------------- decode
def attn_block_decode(params, x, cfg: ModelConfig, cache: Dict[str, Any],
                      lengths, window=None):
    """x: [B, d]; cache: this layer's attention cache slice."""
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a_out, cache = mla_mod.mla_decode(params["attn"], h, cfg, cache,
                                          lengths)
    else:
        a_out, cache = attn_mod.attention_decode(params["attn"], h, cfg,
                                                 cache, lengths,
                                                 window=window)
    if cfg.parallel_block:
        f_out, aux = _ffn(params["ffn"], h[:, None], cfg)
        return x + a_out + f_out[:, 0], aux, cache
    x = x + a_out
    h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
    f_out, aux = _ffn(params["ffn"], h2[:, None], cfg)
    return x + f_out[:, 0], aux, cache


def ssm_block_decode(params, x, cfg: ModelConfig, cache: Dict[str, Any]):
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    y, cache = ssm_mod.ssm_decode(params["ssm"], h, cfg, cache)
    return x + y, jnp.zeros((), jnp.float32), cache


# ------------------------------------------------------------- cache builders
def attn_cache_for(cfg: ModelConfig, batch: int, max_len: int, *,
                   abstract: bool, window: Optional[int], dtype=None):
    L = min(max_len, window) if window else max_len
    if cfg.mla is not None:
        return mla_mod.init_mla_cache(cfg, batch, L, abstract=abstract,
                                      dtype=dtype)
    return attn_mod.init_kv_cache(cfg, batch, L, abstract=abstract,
                                  dtype=dtype)


def attn_cache_logical(cfg: ModelConfig):
    return (mla_mod.MLA_CACHE_LOGICAL if cfg.mla is not None
            else attn_mod.KV_CACHE_LOGICAL)
