"""Multi-head Latent Attention (DeepSeek-V2).

KV state is compressed into a rank-``r`` latent (plus a shared RoPE key).
The decode path uses the *absorbed* formulation: queries are projected into
latent space so attention runs directly against the cached latent — the
cache is [B, L, r + rope] instead of [B, L, H, 2*hd], which is what makes
long_500k memory-feasible for deepseek-v2-lite.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.attention import chunked_causal_attention
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_spec
from repro.models.params import ParamSpec

NEG_INF = -1e30


def mla_spec(cfg: ModelConfig) -> Dict[str, Any]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": ParamSpec((d, h, qk), ("embed", "heads", "head_dim")),
        "w_dkv": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("embed", "mla_rank")),
        "kv_norm": rmsnorm_spec(m.kv_lora_rank),
        "w_uk": ParamSpec((m.kv_lora_rank, h, m.qk_nope_head_dim),
                          ("mla_rank", "heads", "head_dim")),
        "w_uv": ParamSpec((m.kv_lora_rank, h, m.v_head_dim),
                          ("mla_rank", "heads", "head_dim")),
        "wo": ParamSpec((h, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def _latent(params, x, cfg: ModelConfig, positions):
    """x [B,S,d] -> (c_kv [B,S,r] normed, k_rope [B,S,rope] roped)."""
    m = cfg.mla
    dkv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None], positions,
                        cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_full(params, x, cfg: ModelConfig, positions, pad_mask=None,
             window=None) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Train/prefill MLA. Returns (out, (c_kv, k_rope)) for cache handoff."""
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.num_heads
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv, k_rope = _latent(params, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (B, S, h, m.qk_rope_head_dim))], axis=-1)
    qc = jnp.concatenate([q_nope, q_rope], axis=-1)
    qc = constrain(qc, "batch", None, "act_heads", None)
    k = constrain(k, "batch", None, "act_heads", None)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = chunked_causal_attention(
        qc, k, v, q_positions=positions, kv_positions=positions,
        kv_valid=pad_mask, window=window, softmax_scale=scale)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, (c_kv, k_rope)


def mla_decode(params, x, cfg: ModelConfig, cache: Dict[str, Any],
               lengths: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """Absorbed one-token decode against the latent cache.

    cache: {"ckv": [B, L, r], "krope": [B, L, rope]}; x: [B, d].
    """
    m = cfg.mla
    B, _ = x.shape
    q = jnp.einsum("bd,dhk->bhk", x, params["wq"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope[:, None], lengths[:, None],
                        cfg.rope_theta)[:, 0]

    c_kv_t, k_rope_t = _latent(params, x[:, None], cfg, lengths[:, None])
    c_kv_t, k_rope_t = c_kv_t[:, 0], k_rope_t[:, 0]

    L = cache["ckv"].shape[1]
    idx = jnp.minimum(lengths, L - 1)

    def upd(c, n, i):
        return jax.lax.dynamic_update_slice(c, n[None], (i, 0))

    ckv = jax.vmap(upd)(cache["ckv"], c_kv_t.astype(cache["ckv"].dtype), idx)
    krope = jax.vmap(upd)(cache["krope"],
                          k_rope_t.astype(cache["krope"].dtype), idx)
    valid = jnp.arange(L)[None, :] < jnp.minimum(lengths + 1, L)[:, None]

    # absorb W_uk into the query: score = (q_nope W_uk) . c_kv + q_rope . k_rope
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope, params["w_uk"])
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bhr,blr->bhl", q_lat, ckv).astype(jnp.float32)
         + jnp.einsum("bhp,blp->bhl", q_rope, krope).astype(jnp.float32)
         ) * scale
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhl,blr->bhr", p.astype(ckv.dtype), ckv
                       ).astype(x.dtype)
    o = jnp.einsum("bhr,rhk->bhk", o_lat, params["w_uv"])
    y = jnp.einsum("bhk,hkd->bd", o, params["wo"])
    return y, {"ckv": ckv, "krope": krope}


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   *, abstract: bool = False, dtype=None) -> Dict[str, Any]:
    m = cfg.mla
    dtype = dtype or jnp.bfloat16
    shapes = {"ckv": (batch, max_len, m.kv_lora_rank),
              "krope": (batch, max_len, m.qk_rope_head_dim)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, dtype) for k, s in shapes.items()}
    return {k: jnp.zeros(s, dtype) for k, s in shapes.items()}


MLA_CACHE_LOGICAL = {"ckv": ("batch", "kv_seq", "mla_rank"),
                     "krope": ("batch", "kv_seq", None)}
