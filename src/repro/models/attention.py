"""GQA/MHA attention: chunked-causal full-sequence path + cached decode.

Full-sequence attention is computed in query chunks (flash-style row
blocking in pure jnp) so the [S, S] score matrix never materializes — this
is what makes the 32k prefill dry-run memory-sane without the Pallas kernel
(which is the TPU fast path, validated separately in interpret mode).

Decode supports both a full KV cache and a fixed-size sliding-window ring
buffer (the documented sub-quadratic variant used at long_500k for
full-attention architectures).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import apply_rope
from repro.models.params import ParamSpec

NEG_INF = -1e30


def attention_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    spec = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        spec["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return spec


def _project_qkv(params, x, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "act_heads", None)
    k = constrain(k, "batch", None, "act_heads", None)
    return q, k, v


def _pick_chunk(seq: int, target: int = 512) -> int:
    if seq <= target:
        return seq
    c = target
    while seq % c != 0:
        c //= 2
        if c == 1:
            return seq
    return c


def chunked_causal_attention(
    q: jax.Array,  # [B, S, H, hd]
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,  # [B, Skv, KV, hd]
    *,
    q_positions: jax.Array,  # [B, S]
    kv_positions: jax.Array,  # [B, Skv]
    kv_valid: Optional[jax.Array] = None,  # [B, Skv] bool
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    dv = v.shape[-1]  # may differ from hd (MLA)
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    qc = _pick_chunk(S)
    n_chunks = S // qc
    qg = q.reshape(B, n_chunks, qc, KV, G, hd)
    qpos = q_positions.reshape(B, n_chunks, qc)

    def one_chunk(args):
        q_i, qpos_i = args  # [B, qc, KV, G, hd], [B, qc]
        # dtype note: dots stay in the input dtype (TPU MXU accumulates in
        # f32 natively); the explicit upcast happens at the softmax. Using
        # preferred_element_type=f32 here would make every cross-shard
        # partial-sum collective f32 (2x bytes).
        s = jnp.einsum("bqkgd,bskd->bkgqs", q_i, k
                       ).astype(jnp.float32) * scale
        causal = qpos_i[:, :, None] >= kv_positions[:, None, :]  # [B, qc, Skv]
        mask = causal
        if window is not None:
            mask &= (qpos_i[:, :, None] - kv_positions[:, None, :]) < window
        if kv_valid is not None:
            mask &= kv_valid[:, None, :]
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
        return o.astype(q_i.dtype)

    out = jax.lax.map(one_chunk, (jnp.moveaxis(qg, 1, 0),
                                  jnp.moveaxis(qpos, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, dv)
    return out


def decode_attention(
    q: jax.Array,  # [B, H, hd] (single new token, rope already applied)
    k_cache: jax.Array,  # [B, L, KV, hd]
    v_cache: jax.Array,  # [B, L, KV, hd]
    kv_valid: jax.Array,  # [B, L] bool
    *,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    B, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,blkd->bkgl", qg, k_cache
                   ).astype(jnp.float32) * scale
    s = jnp.where(kv_valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgl,blkd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, H, hd).astype(q.dtype)


# --------------------------------------------------------------------- cache
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  *, abstract: bool = False, dtype=None) -> Dict[str, Any]:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dtype = dtype or jnp.bfloat16
    shape = (batch, max_len, kv, hd)
    if abstract:
        mk = lambda: jax.ShapeDtypeStruct(shape, dtype)  # noqa: E731
    else:
        mk = lambda: jnp.zeros(shape, dtype)  # noqa: E731
    return {"k": mk(), "v": mk()}


KV_CACHE_LOGICAL = {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
                    "v": ("batch", "kv_seq", "kv_heads", "head_dim")}


def _write_cache(cache_arr: jax.Array, new: jax.Array,
                 idx: jax.Array) -> jax.Array:
    """cache [B, L, KV, hd] <- new [B, KV, hd] at per-batch index idx [B]."""

    def upd(c, n, i):
        return jax.lax.dynamic_update_slice(c, n[None], (i, 0, 0))

    return jax.vmap(upd)(cache_arr, new, idx)


def attention_full(params, x, cfg: ModelConfig, positions,
                   pad_mask=None, window=None):
    """Train/prefill attention over the whole sequence.

    Returns (out [B,S,d], kv) so prefill can also populate a cache.
    """
    q, k, v = _project_qkv(params, x, cfg, positions)
    out = chunked_causal_attention(
        q, k, v, q_positions=positions, kv_positions=positions,
        kv_valid=pad_mask, window=window)
    out = constrain(out, "batch", None, "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, (k, v)


def attention_decode(params, x, cfg: ModelConfig, cache: Dict[str, Any],
                     lengths: jax.Array, *, window: Optional[int] = None
                     ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token decode. x: [B, d]; lengths: [B] tokens already in cache."""
    B = x.shape[0]
    q = jnp.einsum("bd,dhk->bhk", x, params["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, params["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    # rope at absolute position = lengths
    q = apply_rope(q[:, None], lengths[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], lengths[:, None], cfg.rope_theta)[:, 0]

    L = cache["k"].shape[1]
    if window is not None and L == window:
        write_idx = lengths % window
        n_valid = jnp.minimum(lengths + 1, window)
    else:
        write_idx = jnp.minimum(lengths, L - 1)
        n_valid = jnp.minimum(lengths + 1, L)
    k_cache = _write_cache(cache["k"], k.astype(cache["k"].dtype), write_idx)
    v_cache = _write_cache(cache["v"], v.astype(cache["v"].dtype), write_idx)
    kv_valid = jnp.arange(L)[None, :] < n_valid[:, None]

    o = decode_attention(q, k_cache, v_cache, kv_valid)
    y = jnp.einsum("bhk,hkd->bd", o, params["wo"])
    return y, {"k": k_cache, "v": v_cache}


def prefill_into_cache(cache: Dict[str, Any], k: jax.Array, v: jax.Array,
                       ) -> Dict[str, Any]:
    """Copy prefill keys/values into the head of a (longer) decode cache."""
    S = k.shape[1]
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    del S
    return {"k": k_cache, "v": v_cache}
