"""Shared primitive layers: RMSNorm, RoPE, SwiGLU FFN, embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamSpec


# ----------------------------------------------------------------------- norm
def rmsnorm_spec(d: int):
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # [half]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------------ ffn
def swiglu_spec(d: int, d_ff: int):
    return {
        "w_gate": ParamSpec((d, d_ff), ("embed", "ff")),
        "w_up": ParamSpec((d, d_ff), ("embed", "ff")),
        "w_down": ParamSpec((d_ff, d), ("ff", "embed")),
    }


def swiglu(params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g) * u
    # NOTE: leading dim must stay "batch" — P(None, ...) would FORCE batch
    # replication (None = replicated, not "unspecified") and GSPMD would
    # all-gather every activation across the data axis.
    h = constrain(h, "batch", *((None,) * (h.ndim - 2)), "act_ff")
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ------------------------------------------------------------------ embedding
def embedding_spec(cfg: ModelConfig):
    # std d^-0.5: tied logits h @ embed.T stay O(1); the input side is
    # rescaled by sqrt(d) in embed_tokens (Gemma/Cohere convention).
    spec = {"embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"),
                               scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        spec["out_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"))
    return spec


def embed_tokens(params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.tie_embeddings:
        # scale tied embeddings so logits stay O(1) (Gemma/Cohere style)
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def output_head_weight(params, cfg: ModelConfig) -> jax.Array:
    """[d_model, vocab] matrix producing logits."""
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["out_head"]


def logits_from_hidden(params, hidden: jax.Array, cfg: ModelConfig,
                       w: Optional[jax.Array] = None) -> jax.Array:
    w = output_head_weight(params, cfg) if w is None else w
    logits = jnp.einsum("...d,dv->...v", hidden, w,
                        preferred_element_type=jnp.float32)
    return constrain(logits, "batch", *((None,) * (logits.ndim - 2)),
                     "vocab")
