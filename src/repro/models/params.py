"""Parameter spec trees.

Model code declares parameters as nested dicts of ``ParamSpec`` (shape +
logical axis names + init kind). From one spec tree we derive:

* concrete initialized params (``init_from_specs``),
* abstract ``ShapeDtypeStruct`` stand-ins for the dry-run,
* ``NamedSharding`` trees from the active ``ShardingEnv``.

This keeps model definitions framework-free (no flax) while still carrying
the logical-axis metadata GSPMD needs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingEnv


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | a_log | dt_bias | conv
    scale: Optional[float] = None  # stddev override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


SpecTree = Dict[str, Any]  # nested dicts of ParamSpec


def _fan_in(shape: Tuple[int, ...]) -> int:
    # weights are stored input-major: all but the last axis feed the output
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    return int(np.prod(shape[:-1]))


def _init_leaf(spec: ParamSpec, key: jax.Array, dtype: Any) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "a_log":
        # Mamba2 A in [1, 16]
        lo, hi = 1.0, 16.0
        u = jax.random.uniform(key, spec.shape, jnp.float32)
        return jnp.log(lo + u * (hi - lo)).astype(dtype)
    if spec.init == "dt_bias":
        # inverse softplus of dt ~ U[1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32)
        dt = jnp.exp(u * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    std = spec.scale if spec.scale is not None else _fan_in(spec.shape) ** -0.5
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def _walk(tree: SpecTree, path=()):  # yields (path, spec)
    for k in sorted(tree):
        v = tree[k]
        if isinstance(v, dict):
            yield from _walk(v, path + (k,))
        else:
            yield path + (k,), v


def init_from_specs(specs: SpecTree, key: jax.Array, dtype: Any) -> Any:
    out: Dict[str, Any] = {}
    for path, spec in _walk(specs):
        sub = out
        for p in path[:-1]:
            sub = sub.setdefault(p, {})
        leaf_key = jax.random.fold_in(key, hash("/".join(path)) % (2**31))
        sub[path[-1]] = _init_leaf(spec, leaf_key, dtype)
    return out


def abstract_from_specs(specs: SpecTree, dtype: Any) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def shardings_from_specs(specs: SpecTree, env: ShardingEnv) -> Any:
    return jax.tree.map(
        lambda s: env.sharding(s.shape, s.logical),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def logical_axes_tree(specs: SpecTree) -> Any:
    return jax.tree.map(
        lambda s: s.logical, specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def count_params(specs: SpecTree) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _walk(specs))


def stack_specs(spec: SpecTree, n: int, axis_name: str = "layers") -> SpecTree:
    """Prepend a stacked (scan) axis to every leaf of a block spec tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.logical,
                            s.init, s.scale),
        spec,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
