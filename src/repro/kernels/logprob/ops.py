"""Jit'd wrapper for the fused logprob kernel with backend dispatch.

On TPU this calls the Pallas kernel (compiled); everywhere else it uses the
pure-jnp oracle (the kernel itself is validated against the oracle in
interpret mode by the test suite). A custom_vjp supplies the analytic
backward pass — d/dh logp = w[:, t] - E_p[w], which never needs the full
logits either.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.logprob.kernel import token_logprob_entropy_pallas
from repro.kernels.logprob.ref import token_logprob_entropy_ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def token_logprob_entropy(hidden: jax.Array, w: jax.Array,
                          targets: jax.Array, *, interpret: bool = False
                          ) -> Tuple[jax.Array, jax.Array]:
    """hidden [..., d], w [d, V], targets [...] -> (logp, entropy) [...]."""
    lead = hidden.shape[:-1]
    h2 = hidden.reshape(-1, hidden.shape[-1])
    t2 = targets.reshape(-1)
    if _use_pallas() or interpret:
        logp, ent = token_logprob_entropy_pallas(
            h2, w, t2, interpret=not _use_pallas())
    else:
        logp, ent = token_logprob_entropy_ref(h2, w, t2)
    return logp.reshape(lead), ent.reshape(lead)
