from repro.kernels.logprob.ops import token_logprob_entropy  # noqa: F401
from repro.kernels.logprob.ref import token_logprob_entropy_ref  # noqa: F401
