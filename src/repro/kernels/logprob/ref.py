"""Pure-jnp oracle for the fused token-logprob + entropy kernel.

Materializes the full [T, V] logits — fine as an oracle and for small-vocab
CPU runs; the Pallas kernel streams vocab blocks through VMEM instead.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def token_logprob_entropy_ref(hidden: jax.Array, w: jax.Array,
                              targets: jax.Array
                              ) -> Tuple[jax.Array, jax.Array]:
    """hidden [T, d], w [d, V], targets [T] -> (logp [T], entropy [T]).

    Upcast via astype (not preferred_element_type) so the backward
    cotangent w.r.t. hidden is cast back to the model dtype — otherwise an
    f32 residual-stream cotangent doubles every backward collective."""
    logits = jnp.einsum("td,dv->tv", hidden.astype(jnp.float32),
                        w.astype(jnp.float32))
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    logp = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0] - logz
    p = jax.nn.softmax(logits, axis=-1)
    entropy = logz - jnp.sum(p * logits, axis=-1)
    return logp, entropy
