"""Pallas TPU kernel: fused token logprob + entropy over a blocked vocab.

This is the hot spot the paper's "recompute" baseline pays for: scoring
every token against a (up to 256k-entry) vocabulary. The kernel streams
the logits through VMEM with an online max/logsumexp/moment accumulator so
the [T, V] logit matrix never exists in HBM, and the d_model contraction is
itself blocked so every working tile fits VMEM and feeds the MXU with
128-aligned shapes.

Grid: (T/bt, V/bv, D/bd) with D innermost (matmul accumulation), V middle
(online softmax), T outer. Scratch persists across the (V, D) inner loops
for a given T block.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(hidden_ref, w_ref, targets_ref, logp_ref, ent_ref,
            logits_acc, m_ref, l_ref, s_ref, tgt_ref, *, bv: int,
            n_v: int, n_d: int, vocab: int):
    j = pl.program_id(1)  # vocab block
    k = pl.program_id(2)  # d_model block

    # ---- matmul accumulation over d blocks
    @pl.when(k == 0)
    def _init_logits():
        logits_acc[...] = jnp.zeros_like(logits_acc)

    logits_acc[...] += jnp.dot(
        hidden_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    # ---- after the last d block: online softmax update for this v block
    @pl.when(k == n_d - 1)
    def _online_update():
        logits = logits_acc[...]  # [bt, bv] f32
        # mask vocab padding (when vocab % bv != 0 the tail block over-reads)
        v_idx = j * bv + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        valid = v_idx < vocab
        logits = jnp.where(valid, logits, NEG_INF)

        @pl.when(j == 0)
        def _init_stats():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            s_ref[...] = jnp.zeros_like(s_ref)
            tgt_ref[...] = jnp.zeros_like(tgt_ref)

        m_prev, l_prev, s_prev = m_ref[...], l_ref[...], s_ref[...]
        m_blk = jnp.max(logits, axis=1)
        m_new = jnp.maximum(m_prev, m_blk)
        corr = jnp.exp(m_prev - m_new)
        p_blk = jnp.exp(logits - m_new[:, None])
        p_blk = jnp.where(valid, p_blk, 0.0)
        l_new = l_prev * corr + jnp.sum(p_blk, axis=1)
        # entropy first moment: sum p_shifted * logits
        s_new = s_prev * corr + jnp.sum(
            p_blk * jnp.where(valid, logits, 0.0), axis=1)
        m_ref[...], l_ref[...], s_ref[...] = m_new, l_new, s_new

        # gather the target logit if it lives in this vocab block
        tgt = targets_ref[...]  # [bt]
        local = tgt - j * bv
        in_blk = (local >= 0) & (local < bv)
        one_hot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
                   == jnp.clip(local, 0, bv - 1)[:, None])
        tgt_logit = jnp.sum(jnp.where(one_hot, logits, 0.0), axis=1)
        tgt_ref[...] += jnp.where(in_blk, tgt_logit, 0.0)

        @pl.when(j == n_v - 1)
        def _finalize():
            logz = m_ref[...] + jnp.log(l_ref[...])
            logp_ref[...] = tgt_ref[...] - logz
            ent_ref[...] = logz - s_ref[...] / l_ref[...]


@functools.partial(jax.jit, static_argnames=("bt", "bv", "bd", "interpret"))
def token_logprob_entropy_pallas(
    hidden: jax.Array,  # [T, d]
    w: jax.Array,       # [d, V]
    targets: jax.Array,  # [T] int32
    *, bt: int = 256, bv: int = 512, bd: int = 512,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    T, d = hidden.shape
    V = w.shape[1]
    bt = min(bt, T)
    bv = min(bv, V)
    bd = min(bd, d)
    n_t = pl.cdiv(T, bt)
    n_v = pl.cdiv(V, bv)
    n_d = pl.cdiv(d, bd)
    # pad to exact block multiples (zero pads are correct for the d
    # contraction; padded vocab columns are masked inside the kernel and
    # padded token rows are sliced off below)
    Tp, dp, Vp = n_t * bt, n_d * bd, n_v * bv
    hidden = jnp.pad(hidden, ((0, Tp - T), (0, dp - d)))
    w = jnp.pad(w, ((0, dp - d), (0, Vp - V)))
    targets = jnp.pad(targets, (0, Tp - T))

    kernel = functools.partial(_kernel, bv=bv, n_v=n_v, n_d=n_d, vocab=V)
    out_shape = (jax.ShapeDtypeStruct((Tp,), jnp.float32),
                 jax.ShapeDtypeStruct((Tp,), jnp.float32))
    logp, ent = pl.pallas_call(
        kernel,
        grid=(n_t, n_v, n_d),
        in_specs=[
            pl.BlockSpec((bt, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bd, bv), lambda i, j, k: (k, j)),
            pl.BlockSpec((bt,), lambda i, j, k: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((bt,), lambda i, j, k: (i,)),
            pl.BlockSpec((bt,), lambda i, j, k: (i,)),
        ),
        scratch_shapes=[
            pltpu.VMEM((bt, bv), jnp.float32),  # logits accumulator
            pltpu.VMEM((bt,), jnp.float32),     # running max
            pltpu.VMEM((bt,), jnp.float32),     # running sum-exp
            pltpu.VMEM((bt,), jnp.float32),     # running sum p*logit
            pltpu.VMEM((bt,), jnp.float32),     # target logit
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(hidden, w, targets)
    return logp[:T], ent[:T]
