"""Dispatch wrapper for chunked paged prefill attention."""
from __future__ import annotations

import jax

from repro.kernels.prefill_attn.kernel import paged_prefill_attention_pallas
from repro.kernels.prefill_attn.ref import paged_prefill_attention_ref


def paged_prefill_attention_op(q: jax.Array, pool_k: jax.Array,
                               pool_v: jax.Array, block_tables: jax.Array,
                               seg_ids: jax.Array, q_pos: jax.Array,
                               kv_lens: jax.Array, *,
                               interpret: bool = False) -> jax.Array:
    """Segment-packed prefill attention over one layer's paged pool.

    q [C,H,hd]; pool_k/v [n_blocks,bs,KV,hd]; block_tables [S,max_blocks]
    (-1 = unmapped); seg_ids [C] slot per row (-1 = padding); q_pos [C]
    absolute positions; kv_lens [S] resident-token counts -> [C,H,hd].

    TPU: the Pallas kernel walks the block table inside the kernel (no
    dense per-slot materialization). Elsewhere: the XLA-gather reference
    (or the kernel in interpret mode when ``interpret=True``, for tests).
    The reference ignores ``kv_lens`` — per-row inclusive lengths already
    mask everything; the kernel uses it only to skip empty key blocks.
    """
    if jax.default_backend() == "tpu" or interpret:
        return paged_prefill_attention_pallas(
            q, pool_k, pool_v, block_tables, seg_ids, q_pos, kv_lens,
            interpret=jax.default_backend() != "tpu")
    return paged_prefill_attention_ref(q, pool_k, pool_v, block_tables,
                                       seg_ids, q_pos)
