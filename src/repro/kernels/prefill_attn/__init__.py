"""Chunked paged prefill attention (segment-packed, block-table walk)."""
from repro.kernels.prefill_attn.ops import paged_prefill_attention_op
from repro.kernels.prefill_attn.ref import paged_prefill_attention_ref

__all__ = ["paged_prefill_attention_op", "paged_prefill_attention_ref"]
