"""Pallas TPU kernel: chunked, segment-packed paged prefill attention.

The decode kernel (``decode_attn/paged_kernel.py``) processes one query
per sequence; prefill needs *many* queries per sequence — whole prompt
chunks, possibly several short prompts packed into one launch. This
kernel keeps the decode kernel's scalar-prefetch block-table walk (the
k/v ``index_map`` selects the physical pool block per (segment,
key-block) grid cell, so only ``block_size`` rows of K/V stream through
VMEM at a time and no dense per-slot view is ever built) but carries the
whole chunk of queries ``[C, hd]`` through the sweep with a per-row
online-softmax accumulator.

Grid: ``(n_heads, n_seqs, max_blocks_per_seq)``. For a fixed head the
(s, j) sweep visits every segment's mapped blocks; each row accumulates
only blocks of its own segment at key positions at or before its own
(``seg_ids[i] == s and kpos <= q_pos[i]``) — causal within the chunk,
isolated across packed prompts. Segments with no resident keys (idle
slots) are skipped via the prefetched per-segment key counts. Padding
rows (``seg_ids[i] < 0``) never match a segment, so their accumulator
stays empty and they emit zeros. GQA is handled in the index_map (head h
reads kv-head ``h // G``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables_ref, kv_lens_ref, seg_ref, pos_ref, q_ref, k_ref, v_ref,
            o_ref, acc, m_ref, l_ref, *, bs: int, n_seg: int, n_b: int,
            scale: float):
    s_i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((s_i == 0) & (j == 0))
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip key blocks past this segment's resident-token count (block j
    # covers positions [j*bs, (j+1)*bs); unmapped table entries are
    # clamped to block 0 by the wrapper and always land in skipped or
    # masked territory)
    @pl.when(j * bs < kv_lens_ref[s_i])
    def _accumulate():
        q = q_ref[...].astype(jnp.float32)       # [C, hd]
        k = k_ref[...].astype(jnp.float32)       # [bs, hd]
        v = v_ref[...].astype(jnp.float32)       # [bs, hd]
        seg = seg_ref[...]                       # [C, 1]
        pos = pos_ref[...]                       # [C, 1]

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        # own segment only, causally up to and including the row's own
        # position (its K/V is written to the pool before attention)
        mask = (seg == s_i) & (kpos <= pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]  # [C, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        # the mask factor kills rows whose running max is still NEG_INF
        # (padding / no keys yet): there exp(s - m_new) == exp(0) == 1
        p = jnp.exp(s - m_new) * mask.astype(jnp.float32)  # [C, bs]
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc[...] = acc[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when((s_i == n_seg - 1) & (j == n_b - 1))
    def _done():
        # rows that accumulated nothing (padding) have l == 0 -> emit 0
        o_ref[...] = (acc[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention_pallas(q: jax.Array, pool_k: jax.Array,
                                   pool_v: jax.Array,
                                   block_tables: jax.Array,
                                   seg_ids: jax.Array, q_pos: jax.Array,
                                   kv_lens: jax.Array, *,
                                   interpret: bool = True) -> jax.Array:
    """q [C,H,hd]; pool_k/v [n_blocks,bs,KV,hd] (one layer's pool);
    block_tables [S,max_blocks] int32 (-1 = unmapped); seg_ids [C] slot
    per row (-1 = padding); q_pos [C] absolute positions; kv_lens [S]
    per-segment resident-token counts (block-skip) -> [C,H,hd]."""
    C, H, hd = q.shape
    bs = pool_k.shape[1]
    KV = pool_k.shape[2]
    S, mb = block_tables.shape
    G = H // KV
    tables = jnp.maximum(block_tables, 0).astype(jnp.int32)
    kernel = functools.partial(_kernel, bs=bs, n_seg=S, n_b=mb,
                               scale=hd ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(H, S, mb),
        in_specs=[
            pl.BlockSpec((C, 1), lambda h, s, j, tbl, ln: (0, 0)),
            pl.BlockSpec((C, 1), lambda h, s, j, tbl, ln: (0, 0)),
            pl.BlockSpec((C, None, hd), lambda h, s, j, tbl, ln: (0, h, 0)),
            # the paged gather: physical block straight from the table
            pl.BlockSpec((None, bs, None, hd),
                         lambda h, s, j, tbl, ln, G=G: (tbl[s, j], 0,
                                                        h // G, 0)),
            pl.BlockSpec((None, bs, None, hd),
                         lambda h, s, j, tbl, ln, G=G: (tbl[s, j], 0,
                                                        h // G, 0)),
        ],
        out_specs=pl.BlockSpec((C, None, hd),
                               lambda h, s, j, tbl, ln: (0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, hd), jnp.float32),
            pltpu.VMEM((C, 1), jnp.float32),
            pltpu.VMEM((C, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, H, hd), q.dtype),
        interpret=interpret,
    )(tables, kv_lens.astype(jnp.int32),
      seg_ids.astype(jnp.int32)[:, None], q_pos.astype(jnp.int32)[:, None],
      q, pool_k, pool_v)
