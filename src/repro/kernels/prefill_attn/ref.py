"""Oracle for chunked paged prefill attention.

A prefill chunk is a batch of ``C`` query rows, each tagged with the
serving slot it belongs to (``seg_ids``) and its absolute position in
that slot's sequence (``q_pos``). Row ``i`` must attend exactly the keys
a decode step at position ``q_pos[i]`` would see: everything its slot
has resident in the paged pool up to and *including* itself (the chunk
writes each row's K/V into the pool before attending). That makes the
reference a one-liner on top of ``paged_decode_attention_ref`` — give
every row its own slot's block table and an inclusive length — and makes
the per-row math bit-identical to the per-token decode-replay path the
chunk lane replaces. Causal masking within the chunk and isolation
between packed prompts both fall out of the per-row lengths/tables: a
row can never see positions past its own, nor blocks outside its slot's
table.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.ref import paged_decode_attention_ref


def paged_prefill_attention_ref(q: jax.Array, pool_k: jax.Array,
                                pool_v: jax.Array, block_tables: jax.Array,
                                seg_ids: jax.Array, q_pos: jax.Array
                                ) -> jax.Array:
    """q [C,H,hd]; pool_k/v [n_blocks,bs,KV,hd]; block_tables [S,mb]
    (-1 = unmapped); seg_ids [C] slot per row (-1 = padding row);
    q_pos [C] absolute position per row -> [C,H,hd] (0 for padding)."""
    row_tables = block_tables[jnp.maximum(seg_ids, 0)]       # [C, mb]
    out = paged_decode_attention_ref(q, pool_k, pool_v, row_tables,
                                     q_pos + 1)
    return jnp.where((seg_ids >= 0)[:, None, None], out,
                     jnp.zeros_like(out))
