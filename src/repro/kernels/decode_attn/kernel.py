"""Pallas TPU kernel: single-token (decode) flash attention over a KV cache.

The serving hot spot: one query row per sequence against a [L, KV, hd]
cache. GPU implementations (PagedAttention) split work across warps per
sequence; the TPU adaptation streams key blocks of the cache through VMEM
along the innermost grid axis with an online-softmax accumulator per
(sequence, head), masking by the per-sequence length. GQA is handled in
the k/v index_map (head h reads kv-head h // G).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc, m_ref, l_ref, *,
            bk: int, n_k: int, scale: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)           # [1, hd]
    k = k_ref[...].astype(jnp.float32)           # [bk, hd]
    v = v_ref[...].astype(jnp.float32)           # [bk, hd]
    n_valid = len_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)[0] * scale  # [bk]
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    s = jnp.where(kpos < n_valid, s, NEG_INF)

    m_prev, l_prev = m_ref[0], l_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[0] = l_prev * corr + jnp.sum(p)
    acc[...] = acc[...] * corr + jnp.dot(
        p[None, :], v, preferred_element_type=jnp.float32)
    m_ref[0] = m_new

    @pl.when(j == n_k - 1)
    def _done():
        o_ref[...] = (acc[...] / jnp.maximum(l_ref[0], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, lengths: jax.Array, *,
                            bk: int = 256, interpret: bool = True
                            ) -> jax.Array:
    """q [B,H,hd]; k/v_cache [B,L,KV,hd]; lengths [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    L, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    bk = min(bk, L)
    assert L % bk == 0, "cache length must be a block multiple"
    n_k = L // bk
    # layout: [B, KV, L, hd] so the key block is contiguous per head
    kc = jnp.swapaxes(k_cache, 1, 2)
    vc = jnp.swapaxes(v_cache, 1, 2)
    kernel = functools.partial(_kernel, bk=bk, n_k=n_k, scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_k),
        in_specs=[
            pl.BlockSpec((None, None, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((None, None, bk, hd),
                         lambda b, h, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((None, None, bk, hd),
                         lambda b, h, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
        ],
        out_specs=pl.BlockSpec((None, None, 1, hd),
                               lambda b, h, j: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        interpret=interpret,
    )(q[:, :, None, :], kc, vc, lengths)[:, :, 0, :]
