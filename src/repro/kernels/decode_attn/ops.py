"""Dispatch wrappers for decode attention (dense-cache and paged)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attention_pallas
from repro.kernels.decode_attn.paged_kernel import (
    paged_decode_attention_pallas,
)
from repro.kernels.decode_attn.ref import paged_decode_attention_ref
from repro.models.attention import decode_attention as _ref


def decode_attention_op(q: jax.Array, k_cache: jax.Array,
                        v_cache: jax.Array, lengths: jax.Array, *,
                        interpret: bool = False) -> jax.Array:
    """q [B,H,hd]; caches [B,L,KV,hd]; lengths [B] valid-token counts."""
    if jax.default_backend() == "tpu" or interpret:
        return decode_attention_pallas(
            q, k_cache, v_cache, lengths,
            interpret=jax.default_backend() != "tpu")
    L = k_cache.shape[1]
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    return _ref(q, k_cache, v_cache, valid)


def paged_decode_attention_op(q: jax.Array, pool_k: jax.Array,
                              pool_v: jax.Array, block_tables: jax.Array,
                              lengths: jax.Array, *,
                              interpret: bool = False) -> jax.Array:
    """Block-table-aware decode attention over one layer's paged pool.

    q [S,H,hd]; pool_k/v [n_blocks,bs,KV,hd]; block_tables [S,max_blocks]
    (-1 = unmapped); lengths [S] valid-token counts -> [S,H,hd].

    TPU: the Pallas kernel gathers K/V through the block table inside the
    kernel (no dense ``max_blocks * bs`` materialization per slot).
    Elsewhere: the XLA-gather reference (or the kernel in interpret mode
    when ``interpret=True``, for tests).
    """
    if jax.default_backend() == "tpu" or interpret:
        return paged_decode_attention_pallas(
            q, pool_k, pool_v, block_tables, lengths,
            interpret=jax.default_backend() != "tpu")
    return paged_decode_attention_ref(q, pool_k, pool_v, block_tables,
                                      lengths)
