"""Dispatch wrapper for decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attention_pallas
from repro.models.attention import decode_attention as _ref


def decode_attention_op(q: jax.Array, k_cache: jax.Array,
                        v_cache: jax.Array, lengths: jax.Array, *,
                        interpret: bool = False) -> jax.Array:
    """q [B,H,hd]; caches [B,L,KV,hd]; lengths [B] valid-token counts."""
    if jax.default_backend() == "tpu" or interpret:
        return decode_attention_pallas(
            q, k_cache, v_cache, lengths,
            interpret=jax.default_backend() != "tpu")
    L = k_cache.shape[1]
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    return _ref(q, k_cache, v_cache, valid)
