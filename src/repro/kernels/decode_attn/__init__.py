from repro.kernels.decode_attn.kernel import (  # noqa: F401
    decode_attention_pallas,
)
from repro.kernels.decode_attn.ops import decode_attention_op  # noqa: F401
