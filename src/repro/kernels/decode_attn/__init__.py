from repro.kernels.decode_attn.kernel import (  # noqa: F401
    decode_attention_pallas,
)
from repro.kernels.decode_attn.ops import (  # noqa: F401
    decode_attention_op,
    paged_decode_attention_op,
)
from repro.kernels.decode_attn.paged_kernel import (  # noqa: F401
    paged_decode_attention_pallas,
)
