"""Oracles for decode attention (shared with models.attention).

``paged_decode_attention_ref`` is the XLA-gather adaptation of the paged
pointer walk: index the dense block pool with the block table (one gather)
and run the regular masked decode attention over the result. It is both
the correctness oracle for the Pallas paged kernel and the non-TPU
dispatch path of ``paged_decode_attention_op``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention as decode_attention_ref  # noqa: F401


def paged_decode_attention_ref(q: jax.Array, pool_k: jax.Array,
                               pool_v: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """q [S,H,hd]; pool_k/v [n_blocks,bs,KV,hd]; block_tables [S,mb]
    (-1 = unmapped); lengths [S] valid-token counts -> [S,H,hd]."""
    S, mb = block_tables.shape
    bs = pool_k.shape[1]
    safe = jnp.maximum(block_tables, 0)
    k = pool_k[safe].reshape(S, mb * bs, *pool_k.shape[2:])
    v = pool_v[safe].reshape(S, mb * bs, *pool_v.shape[2:])
    valid = jnp.arange(mb * bs)[None, :] < lengths[:, None]
    return decode_attention_ref(q, k, v, valid)
