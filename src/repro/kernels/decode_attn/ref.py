"""Oracle for single-token decode attention (shared with models.attention)."""
from repro.models.attention import decode_attention as decode_attention_ref  # noqa: F401
