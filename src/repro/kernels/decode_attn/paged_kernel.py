"""Pallas TPU kernel: paged (block-table) single-token decode attention.

GPU PagedAttention walks the block table with pointer indirection inside
the kernel; the XLA fallback in ``rollout.paged_cache.gather_kv``
materializes a dense ``[S, max_blocks * block_size, KV, hd]`` view per
layer instead — fine for toy pools, ruinous for production ones. This
kernel is the TPU-native middle ground: the block table and sequence
lengths ride in as *scalar-prefetch* operands, so the k/v ``index_map``
selects the physical pool block for each (sequence, key-block) grid cell
and only ``block_size`` rows of K/V ever stream through VMEM at a time.
No dense per-slot materialization of the pool happens at any point.

Grid: ``(n_seqs, n_heads, max_blocks_per_seq)`` with an online-softmax
accumulator over the innermost (key-block) axis, masked by the
per-sequence valid-token count. GQA is handled in the index_map (head h
reads kv-head ``h // G``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables_ref, len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_ref,
            l_ref, *, bs: int, n_b: int, scale: float):
    s_i = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)           # [1, hd]
    k = k_ref[...].astype(jnp.float32)           # [bs, hd]
    v = v_ref[...].astype(jnp.float32)           # [bs, hd]
    n_valid = len_ref[s_i]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)[0] * scale  # [bs]
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
    # positions past the valid count are masked; unmapped (-1) table
    # entries are clamped to block 0 by the wrapper and always fall in
    # the masked region (a sequence's valid tokens live in mapped blocks)
    s = jnp.where(kpos < n_valid, s, NEG_INF)

    m_prev, l_prev = m_ref[0], l_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[0] = l_prev * corr + jnp.sum(p)
    acc[...] = acc[...] * corr + jnp.dot(
        p[None, :], v, preferred_element_type=jnp.float32)
    m_ref[0] = m_new

    @pl.when(j == n_b - 1)
    def _done():
        o_ref[...] = (acc[...] / jnp.maximum(l_ref[0], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q: jax.Array, pool_k: jax.Array,
                                  pool_v: jax.Array, block_tables: jax.Array,
                                  lengths: jax.Array, *,
                                  interpret: bool = True) -> jax.Array:
    """q [S,H,hd]; pool_k/v [n_blocks,bs,KV,hd] (one layer's pool);
    block_tables [S,max_blocks] int32 (-1 = unmapped); lengths [S]
    valid-token counts -> [S,H,hd]."""
    S, H, hd = q.shape
    bs, KV = pool_k.shape[1], pool_k.shape[2]
    mb = block_tables.shape[1]
    G = H // KV
    tables = jnp.maximum(block_tables, 0).astype(jnp.int32)
    kernel = functools.partial(_kernel, bs=bs, n_b=mb, scale=hd ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, H, mb),
        in_specs=[
            pl.BlockSpec((None, None, 1, hd),
                         lambda s, h, j, tbl, ln: (s, h, 0, 0)),
            # the paged gather: physical block straight from the table
            pl.BlockSpec((None, bs, None, hd),
                         lambda s, h, j, tbl, ln, G=G: (tbl[s, j], 0,
                                                        h // G, 0)),
            pl.BlockSpec((None, bs, None, hd),
                         lambda s, h, j, tbl, ln, G=G: (tbl[s, j], 0,
                                                        h // G, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, 1, hd),
                               lambda s, h, j, tbl, ln: (s, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, H, 1, hd), q.dtype),
        interpret=interpret,
    )(tables, lengths, q[:, :, None, :], pool_k, pool_v)[:, :, 0, :]
