"""Pure-jnp oracle for causal (windowed) flash attention."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, window: Optional[int] = None) -> jax.Array:
    """q [B,H,S,hd], k/v [B,KV,S,hd] (GQA) -> [B,H,S,hd]."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    i = jnp.arange(S)
    mask = i[:, None] >= i[None, :]
    if window is not None:
        mask &= (i[:, None] - i[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, S, hd).astype(q.dtype)
