"""Pallas TPU kernel: causal flash attention with GQA-aware BlockSpecs.

TPU adaptation of the (GPU warp-shuffle) flash algorithm: query-row blocks
live in VMEM, key/value blocks stream through the innermost grid axis, and
the online (m, l, acc) softmax state sits in VMEM scratch. GQA is handled
in the k/v index_map (head h reads kv-head h // G), so grouped heads never
materialize repeated K/V in HBM. Fully-future key blocks are skipped with
``pl.when`` — the TPU equivalent of the GPU kernel's early-exit, giving the
~2x causal FLOP saving.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_ref, l_ref, *,
            bq: int, bk: int, n_k: int, scale: float,
            window: Optional[int]):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # k block

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * bq
    k_start = j * bk
    needed = k_start <= q_start + bq - 1  # causal: any k <= max q pos
    if window is not None:
        needed &= (q_start - (k_start + bk - 1)) < window

    @pl.when(needed)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc[...] = acc[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "window", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           bq: int = 256, bk: int = 256,
                           window: Optional[int] = None,
                           interpret: bool = True) -> jax.Array:
    """q [B,H,S,hd], k/v [B,KV,S,hd] -> [B,H,S,hd] (causal)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, "pad seq to block multiple"
    n_q, n_k = S // bq, S // bk
    kernel = functools.partial(_kernel, bq=bq, bk=bk, n_k=n_k,
                               scale=hd ** -0.5, window=window)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
