"""Dispatch wrapper for flash attention."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: Optional[int] = None,
                    interpret: bool = False) -> jax.Array:
    if jax.default_backend() == "tpu" or interpret:
        return flash_attention_pallas(
            q, k, v, window=window, interpret=jax.default_backend() != "tpu")
    return flash_attention_ref(q, k, v, window=window)
