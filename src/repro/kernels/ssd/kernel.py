"""Pallas TPU kernel: Mamba2 SSD intra-chunk block.

The GPU reference fuses the whole selective scan into one CUDA kernel built
around warp-level prefix products. On TPU we exploit the state-space
*duality* instead: within a chunk the recurrence is exactly a masked
attention-like matmul (MXU work), and only the tiny inter-chunk recurrence
remains sequential (left in jnp as a lax.scan over S/chunk steps).

Per (batch, chunk, head) grid cell this kernel computes:
  y_intra[i] = sum_{j<=i} (C_i . B_j) exp(cum_i - cum_j) dt_j x_j
  s_local    = sum_j exp(cum_last - cum_j) (dt_j x_j) B_j^T
  cdec       = exp(cum_last)
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, la_ref, b_ref, c_ref, y_ref, s_ref, cdec_ref, *,
            chunk: int):
    x = x_ref[...].astype(jnp.float32)     # [cs, hd]
    la = la_ref[...].astype(jnp.float32)   # [cs]
    b = b_ref[...].astype(jnp.float32)     # [cs, ds]
    c = c_ref[...].astype(jnp.float32)     # [cs, ds]

    cum = jnp.cumsum(la)                       # [cs]
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # [cs, cs]
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    m = cb * decay
    y_ref[...] = jnp.dot(m, x, preferred_element_type=jnp.float32
                         ).astype(y_ref.dtype)

    decay_last = jnp.exp(cum[-1] - cum)        # [cs]
    s_ref[...] = jnp.dot((x * decay_last[:, None]).T, b,
                         preferred_element_type=jnp.float32
                         ).astype(s_ref.dtype)  # [hd, ds]
    cdec_ref[...] = jnp.exp(cum[-1:]).astype(cdec_ref.dtype)


def _decode_kernel(state_ref, x_ref, dt_ref, alog_ref, b_ref, c_ref,
                   y_ref, new_state_ref):
    state = state_ref[...].astype(jnp.float32)   # [hd, ds]
    x = x_ref[...].astype(jnp.float32)           # [hd]
    dt = dt_ref[0].astype(jnp.float32)
    a = jnp.exp(dt * (-jnp.exp(alog_ref[0].astype(jnp.float32))))
    b = b_ref[...].astype(jnp.float32)           # [ds]
    new = state * a + (dt * x)[:, None] * b[None, :]
    new_state_ref[...] = new.astype(new_state_ref.dtype)
    c = c_ref[...].astype(jnp.float32)           # [ds]
    y_ref[...] = jnp.dot(new, c, preferred_element_type=jnp.float32
                         ).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_decode_step_pallas(
    state: jax.Array,  # [B, nh, hd, ds] f32
    x: jax.Array,      # [B, nh, hd]
    dt: jax.Array,     # [B, nh]  (softplus'd)
    a_log: jax.Array,  # [nh]
    b: jax.Array,      # [B, ds]
    c: jax.Array,      # [B, ds]
    *, interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """One recurrent SSD decode step per (batch, head) grid cell.

    Returns (y [B,nh,hd] in x's dtype, new state [B,nh,hd,ds] f32).
    """
    B, nh, hd, ds = state.shape
    y, new_state = pl.pallas_call(
        _decode_kernel,
        grid=(B, nh),
        in_specs=[
            pl.BlockSpec((None, None, hd, ds), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, hd), lambda bi, hi: (bi, hi, 0)),
            pl.BlockSpec((None, 1), lambda bi, hi: (bi, hi)),
            pl.BlockSpec((1,), lambda bi, hi: (hi,)),
            pl.BlockSpec((None, ds), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((None, ds), lambda bi, hi: (bi, 0)),
        ],
        out_specs=(
            pl.BlockSpec((None, None, hd), lambda bi, hi: (bi, hi, 0)),
            pl.BlockSpec((None, None, hd, ds), lambda bi, hi: (bi, hi, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, nh, hd), x.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32),
        ),
        interpret=interpret,
    )(state, x, dt, a_log, b, c)
    return y, new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_intra_chunk_pallas(
    xdt: jax.Array,   # [B, S, nh, hd]  (x pre-scaled by dt)
    la: jax.Array,    # [B, S, nh]      (log decay per step)
    b: jax.Array,     # [B, S, ds]
    c: jax.Array,     # [B, S, ds]
    *, chunk: int = 256, interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (y_intra [B,S,nh,hd], s_local [B,nc,nh,hd,ds],
    chunk_decay [B,nc,nh])."""
    B, S, nh, hd = xdt.shape
    ds = b.shape[-1]
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    # reshape chunks into a leading axis the grid can walk
    x_c = xdt.reshape(B, nc, chunk, nh, hd)
    la_c = la.reshape(B, nc, chunk, nh)
    b_c = b.reshape(B, nc, chunk, ds)
    c_c = c.reshape(B, nc, chunk, ds)

    kernel = functools.partial(_kernel, chunk=chunk)
    y, s_local, cdec = pl.pallas_call(
        kernel,
        grid=(B, nc, nh),
        in_specs=[
            pl.BlockSpec((None, None, chunk, None, hd),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((None, None, chunk, None),
                         lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((None, None, chunk, ds),
                         lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((None, None, chunk, ds),
                         lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((None, None, chunk, None, hd),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((None, None, None, hd, ds),
                         lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((None, None, 1), lambda bi, ci, hi: (bi, ci, hi)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, nc, chunk, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, nh, hd, ds), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, nh), jnp.float32),
        ),
        interpret=interpret,
    )(x_c, la_c, b_c, c_c)
    return y.reshape(B, S, nh, hd), s_local, cdec
