"""SSD op: Pallas intra-chunk kernel + jnp inter-chunk recurrence.

The combination computes the same y/final-state as the sequential oracle
(``ref.ssd_sequential_ref``) but with all O(chunk^2) work as MXU matmuls.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import (ssd_decode_step_pallas,
                                      ssd_intra_chunk_pallas)
from repro.kernels.ssd.ref import ssd_decode_step_ref, ssd_sequential_ref


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    a_log: jax.Array, b: jax.Array, c: jax.Array,
                    *, interpret: bool = False
                    ) -> Tuple[jax.Array, jax.Array]:
    """O(1) SSD decode step: dispatch to the Pallas kernel on TPU (or in
    interpret mode), else the jnp reference — same convention as
    ``decode_attn``/``prefill_attn``."""
    if not (jax.default_backend() == "tpu" or interpret):
        return ssd_decode_step_ref(state, x, dt, a_log, b, c)
    return ssd_decode_step_pallas(
        state, x, dt, a_log, b, c,
        interpret=jax.default_backend() != "tpu")


def ssd_scan(x: jax.Array, dt: jax.Array, a_log: jax.Array,
             b: jax.Array, c: jax.Array, *, chunk: int = 256,
             initial_state=None, interpret: bool = False
             ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD using the Pallas kernel for the intra-chunk part."""
    if not (jax.default_backend() == "tpu" or interpret):
        return ssd_sequential_ref(x, dt, a_log, b, c, initial_state)

    B, S, nh, hd = x.shape
    ds = b.shape[-1]
    la = dt * (-jnp.exp(a_log.astype(jnp.float32)))
    xdt = x.astype(jnp.float32) * dt[..., None]
    y_intra, s_local, cdec = ssd_intra_chunk_pallas(
        xdt, la, b, c, chunk=chunk,
        interpret=jax.default_backend() != "tpu")
    nc = s_local.shape[1]
    cs = S // nc

    if initial_state is None:
        initial_state = jnp.zeros((B, nh, hd, ds), jnp.float32)

    def step(state, inp):
        s_loc, cd = inp
        new = state * cd[..., None, None] + s_loc
        return new, state

    final, prev_states = jax.lax.scan(
        step, initial_state,
        (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(cdec, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [B,nc,nh,hd,ds]

    # inter-chunk contribution: exp(cum_i) * C_i . S_prev
    cum = jnp.cumsum(la.reshape(B, nc, cs, nh), axis=2)
    c_c = c.reshape(B, nc, cs, ds)
    y_inter = jnp.einsum("bnis,bnhds->bnihd", c_c.astype(jnp.float32),
                         prev_states) * jnp.exp(cum)[..., None]
    y = y_intra + y_inter.reshape(B, S, nh, hd)
    return y.astype(x.dtype), final
