"""Pure-jnp oracle for the SSD intra-chunk kernel: a naive sequential
recurrence (the mathematically-defining form of the SSM)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ssd_decode_step_ref(state: jax.Array, x: jax.Array, dt: jax.Array,
                        a_log: jax.Array, b: jax.Array, c: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """One recurrent SSD step (the O(1) decode update).

    state [B,nh,hd,ds] f32; x [B,nh,hd]; dt [B,nh] (softplus'd);
    b/c [B,ds]. Returns (y [B,nh,hd] in c's dtype, new state f32).
    This is the exact math ``models.ssm.ssm_decode`` historically inlined
    — the serving decode tower and the whole-sequence reference share it,
    so paged SSM decode is bit-identical to the dense-cache path.
    """
    a = jnp.exp(dt * (-jnp.exp(a_log.astype(jnp.float32))))  # [B,nh]
    state = state * a[..., None, None] + jnp.einsum(
        "bh,bhd,bs->bhds", dt, x.astype(jnp.float32),
        b.astype(jnp.float32))
    y = jnp.einsum("bs,bhds->bhd", c, state.astype(c.dtype))
    return y, state


def ssd_sequential_ref(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                       b: jax.Array, c: jax.Array,
                       initial_state=None) -> Tuple[jax.Array, jax.Array]:
    """Step-by-step scan. x [B,S,nh,hd], dt [B,S,nh], b/c [B,S,ds]."""
    B, S, nh, hd = x.shape
    ds = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))  # [nh]
    if initial_state is None:
        initial_state = jnp.zeros((B, nh, hd, ds), jnp.float32)

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t * a)  # [B,nh]
        upd = jnp.einsum("bh,bhd,bs->bhds", dt_t, x_t.astype(jnp.float32),
                         b_t.astype(jnp.float32))
        state = state * decay[..., None, None] + upd
        y_t = jnp.einsum("bs,bhds->bhd", c_t.astype(jnp.float32), state)
        return state, y_t

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    final, ys = jax.lax.scan(step, initial_state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
