"""Pallas TPU kernel: fused A-3PO decoupled loss (beyond-paper fusion).

The paper computes prox interpolation, importance weight, trust-region
ratio, clipping, and masking as ~10 separate elementwise HLO ops over the
[B, T] token grid. This kernel fuses the whole objective into one VMEM
pass — one HBM read per input tensor, one write per output. Alongside the
per-token loss and clip indicators it emits the importance weight and
trust-region ratio, so the training metrics (iw max/min/mean, ratio mean)
come out of the same pass instead of a second elementwise sweep.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logp_ref, behav_ref, alpha_ref, adv_ref, mask_ref,
            loss_ref, clip_ref, iw_ref, ratio_ref, *, clip_eps: float,
            iw_cap: float):
    logp = logp_ref[...].astype(jnp.float32)
    behav = behav_ref[...].astype(jnp.float32)
    alpha = alpha_ref[...].astype(jnp.float32)
    adv = adv_ref[...].astype(jnp.float32)
    mask = mask_ref[...].astype(jnp.float32)

    prox = alpha * behav + (1.0 - alpha) * logp
    iw = jnp.minimum(jnp.exp(prox - behav), iw_cap)
    ratio = jnp.exp(logp - prox)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    obj = jnp.minimum(unclipped, clipped)
    loss_ref[...] = -iw * obj * mask
    clip_ref[...] = (unclipped > clipped).astype(jnp.float32) * mask
    iw_ref[...] = iw
    ratio_ref[...] = ratio


@functools.partial(jax.jit,
                   static_argnames=("clip_eps", "iw_cap", "bt", "interpret"))
def a3po_loss_pallas(logp: jax.Array, behav_logp: jax.Array,
                     alpha: jax.Array, adv: jax.Array, mask: jax.Array, *,
                     clip_eps: float = 0.2, iw_cap: float = 5.0,
                     bt: int = 1024, interpret: bool = True
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    (T,) = logp.shape
    bt = min(bt, T)
    n_t = pl.cdiv(T, bt)
    Tp = n_t * bt
    pad = lambda x: jnp.pad(x, (0, Tp - T))  # noqa: E731
    args = [pad(a) for a in (logp, behav_logp, alpha, adv, mask)]
    kernel = functools.partial(_kernel, clip_eps=clip_eps, iw_cap=iw_cap)
    out_struct = jax.ShapeDtypeStruct((Tp,), jnp.float32)
    loss, clip, iw, ratio = pl.pallas_call(
        kernel,
        grid=(n_t,),
        in_specs=[pl.BlockSpec((bt,), lambda i: (i,))] * 5,
        out_specs=tuple(pl.BlockSpec((bt,), lambda i: (i,))
                        for _ in range(4)),
        out_shape=(out_struct,) * 4,
        interpret=interpret,
    )(*args)
    return loss[:T], clip[:T], iw[:T], ratio[:T]
