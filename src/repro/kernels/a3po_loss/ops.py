"""Dispatch + autodiff wrappers for the fused A-3PO loss.

``a3po_objective`` is the training-path entry point: a ``custom_vjp`` whose
forward pass runs the fused Pallas kernel (interpret mode off-TPU) and whose
backward pass is the analytic elementwise gradient of the clipped surrogate
— no differentiation through ``pallas_call`` is ever needed, and the pure-jnp
``ref.a3po_loss_ref`` serves as the gradient oracle in tests.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.a3po_loss.kernel import a3po_loss_pallas
from repro.kernels.a3po_loss.ref import a3po_loss_ref


def _run_fused(static, logp, behav_logp, alpha, adv, mask):
    clip_eps, iw_cap, use_kernel, interpret = static
    lead = logp.shape
    flat = lambda x: x.astype(jnp.float32).reshape(-1)  # noqa: E731
    args = (flat(logp), flat(behav_logp), flat(alpha), flat(adv), flat(mask))
    if use_kernel:
        outs = a3po_loss_pallas(*args, clip_eps=clip_eps, iw_cap=iw_cap,
                                interpret=interpret)
    else:
        outs = a3po_loss_ref(*args, clip_eps=clip_eps, iw_cap=iw_cap)
    return tuple(o.reshape(lead) for o in outs)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _a3po_objective(static, logp, behav_logp, alpha, adv, mask):
    return _run_fused(static, logp, behav_logp, alpha, adv, mask)


def _a3po_objective_fwd(static, logp, behav_logp, alpha, adv, mask):
    outs = _run_fused(static, logp, behav_logp, alpha, adv, mask)
    _, clip_tok, iw, ratio = outs
    return outs, (clip_tok, iw, ratio, adv, mask)


def _a3po_objective_bwd(static, res, cts):
    # The anchor (prox) and importance weight are frozen (stop_gradient in
    # the modular loss), so the only gradient path is
    #   d loss_tok / d logp = -iw * mask * d obj / d logp
    # with d obj / d logp = ratio * adv on the unclipped branch and 0 where
    # the clip is active (clip_tok already folds the mask in). At exact
    # min-ties both branches carry the same ratio*adv, matching jnp.minimum's
    # split-gradient convention. Cotangents for the metric outputs
    # (clip/iw/ratio) and the data operands are zero by construction.
    clip_tok, iw, ratio, adv, mask = res
    g_loss = cts[0].astype(jnp.float32)
    live = 1.0 - jnp.where(clip_tok > 0, 1.0, 0.0)
    g_logp = g_loss * (-(iw * ratio * adv) * mask * live)
    z = jnp.zeros_like(g_logp)
    return (g_logp, z, z, z, z)


_a3po_objective.defvjp(_a3po_objective_fwd, _a3po_objective_bwd)


def a3po_objective(logp: jax.Array, behav_logp: jax.Array, alpha: jax.Array,
                   adv: jax.Array, mask: jax.Array, *,
                   clip_eps: float = 0.2, iw_cap: float = 5.0,
                   use_kernel: bool = True,
                   interpret: bool = None
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Differentiable fused A-3PO objective over [B, T] (or [T]) tensors.

    Returns per-token ``(loss_tok, clip_tok, iw, ratio)``; ``loss_tok`` is
    the negated, masked clipped surrogate and carries the analytic VJP
    w.r.t. ``logp``. The metric outputs (clip/iw/ratio) are detached —
    stop_gradient makes the zero-cotangent assumption of the backward pass
    mechanically true for any downstream use. On non-TPU backends the
    kernel runs in interpret mode.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    static = (float(clip_eps), float(iw_cap), bool(use_kernel),
              bool(interpret))
    loss_tok, clip_tok, iw, ratio = _a3po_objective(
        static, logp, behav_logp, alpha, adv, mask)
    sg = jax.lax.stop_gradient
    return loss_tok, sg(clip_tok), sg(iw), sg(ratio)


def a3po_loss_fused(logp: jax.Array, behav_logp: jax.Array,
                    alpha: jax.Array, adv: jax.Array, mask: jax.Array, *,
                    clip_eps: float = 0.2, iw_cap: float = 5.0,
                    interpret: bool = False
                    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Forward-only dispatch (benchmarks): kernel on TPU/interpret, else ref."""
    lead = logp.shape
    flat = lambda x: x.reshape(-1)  # noqa: E731
    args = (flat(logp), flat(behav_logp), flat(alpha), flat(adv), flat(mask))
    if jax.default_backend() == "tpu" or interpret:
        outs = a3po_loss_pallas(*args, clip_eps=clip_eps, iw_cap=iw_cap,
                                interpret=jax.default_backend() != "tpu")
    else:
        outs = a3po_loss_ref(*args, clip_eps=clip_eps, iw_cap=iw_cap)
    return tuple(o.reshape(lead) for o in outs)
