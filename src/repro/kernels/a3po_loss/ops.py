"""Dispatch wrapper for the fused A-3PO loss."""
from __future__ import annotations

from typing import Tuple

import jax

from repro.kernels.a3po_loss.kernel import a3po_loss_pallas
from repro.kernels.a3po_loss.ref import a3po_loss_ref


def a3po_loss_fused(logp: jax.Array, behav_logp: jax.Array,
                    alpha: jax.Array, adv: jax.Array, mask: jax.Array, *,
                    clip_eps: float = 0.2, iw_cap: float = 5.0,
                    interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    lead = logp.shape
    flat = lambda x: x.reshape(-1)  # noqa: E731
    if jax.default_backend() == "tpu" or interpret:
        loss, clip = a3po_loss_pallas(
            flat(logp), flat(behav_logp), flat(alpha), flat(adv), flat(mask),
            clip_eps=clip_eps, iw_cap=iw_cap,
            interpret=jax.default_backend() != "tpu")
    else:
        loss, clip = a3po_loss_ref(
            flat(logp), flat(behav_logp), flat(alpha), flat(adv), flat(mask),
            clip_eps=clip_eps, iw_cap=iw_cap)
    return loss.reshape(lead), clip.reshape(lead)
