"""Pure-jnp oracle for the fused A-3PO decoupled-loss kernel.

Differentiable end-to-end (the prox anchor and importance weight are
stop_gradient'ed exactly like the modular loss), so tests can use it as
the gradient oracle for the custom-VJP fused path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def a3po_loss_ref(logp: jax.Array, behav_logp: jax.Array, alpha: jax.Array,
                  adv: jax.Array, mask: jax.Array, *, clip_eps: float,
                  iw_cap: float) -> Tuple[jax.Array, jax.Array, jax.Array,
                                          jax.Array]:
    """Per-token fused A-3PO objective.

    Returns (loss_tok [T] (negated objective, masked), clipped [T] (masked),
    iw [T], ratio [T]). ``iw``/``ratio`` are the raw per-token importance
    weight and trust-region ratio the loss metrics are derived from.
    """
    logp = logp.astype(jnp.float32)
    behav = behav_logp.astype(jnp.float32)
    prox = jax.lax.stop_gradient(alpha * behav + (1.0 - alpha) * logp)
    iw = jax.lax.stop_gradient(jnp.minimum(jnp.exp(prox - behav), iw_cap))
    ratio = jnp.exp(logp - prox)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    obj = jnp.minimum(unclipped, clipped)
    was_clipped = (unclipped > clipped).astype(jnp.float32) * mask
    return -iw * obj * mask, was_clipped, iw, ratio
