from repro.kernels.a3po_loss.ops import (  # noqa: F401
    a3po_loss_fused,
    a3po_objective,
)
from repro.kernels.a3po_loss.ref import a3po_loss_ref  # noqa: F401
