from repro.kernels.a3po_loss.ops import a3po_loss_fused  # noqa: F401
from repro.kernels.a3po_loss.ref import a3po_loss_ref  # noqa: F401
