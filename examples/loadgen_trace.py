"""Trace-driven load harness demo: 2-class bursty overload, SLO vs FIFO.

Synthesizes a bursty two-class workload (latency-critical ``chat`` vs
best-effort ``batch``) that oversubscribes the engine's virtual capacity
about 2x, then replays the *same* trace twice through the serving
control plane on the virtual clock:

* ``fifo`` — no priorities: chat requests queue behind batch bursts and
  blow through their TTFT SLO;
* ``slo`` — priority admission + deadline-aware shedding + overload
  preemption: chat stays inside its SLO, batch absorbs the tail.

Everything is deterministic (seeded trace + virtual clock), so the
numbers printed here are reproducible to the last digit.

Run: PYTHONPATH=src python examples/loadgen_trace.py
"""
import argparse
import dataclasses

import jax

from repro.configs.registry import get_config
from repro.loadgen.harness import CostModel, run_trace
from repro.loadgen.traces import SLOClass, TraceConfig, synthesize
from repro.models import model as M
from repro.obs.report import render_load


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--duration", type=float, default=2.5,
                   help="trace length (virtual seconds)")
    p.add_argument("--rate", type=float, default=14.0,
                   help="mean arrivals/s (~2x virtual capacity)")
    args = p.parse_args()

    classes = (
        SLOClass("chat", 0, ttft_slo_s=0.5, e2e_slo_s=4.0,
                 share=0.35, max_new=8),
        SLOClass("batch", 2, ttft_slo_s=6.0, e2e_slo_s=30.0,
                 share=0.65, max_new=16),
    )
    trace = synthesize(TraceConfig(
        seed=args.seed, duration_s=args.duration, rate_rps=args.rate,
        burstiness=0.5, publish_every_s=1.0), classes)
    print(f"trace: {len(trace.requests)} requests / "
          f"{trace.duration_s:.1f}s, {len(trace.publishes)} publishes\n")

    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # inflated virtual costs: a small trace still queues like an
    # overloaded production box
    cost = CostModel(step_overhead_s=0.010, prefill_chunk_s=0.020,
                     decode_token_s=0.010)

    for policy in ("fifo", "slo"):
        res = run_trace(cfg, params, trace, policy=policy, cost=cost,
                        max_seqs=2)
        print(render_load(res.summary))
        print()

    print("same trace, same engine — only the admission policy changed.")


if __name__ == "__main__":
    main()
