"""Quickstart: the A-3PO approximation + the Algorithm API in ~40 lines.

Shows the paper's core idea standalone — approximate the proximal policy by
staleness-aware log-linear interpolation instead of a forward pass — then
runs the same data through pluggable Algorithm objects from the registry
(the A-3PO built-in routes through the fused kernel path).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import RLConfig
from repro.core.a3po import compute_prox_logp_approximation
from repro.core.algorithms import LossInputs, available, get_algorithm

B, T = 4, 16
key = jax.random.PRNGKey(0)
rl = RLConfig()

# what the rollout engine hands the trainer:
behav_logp = -jax.random.uniform(key, (B, T)) * 2       # log pi_behav
versions = jnp.array([0, 1, 2, 3])                      # behavior versions
current_version = 3                                     # v(pi_theta)

# what the live policy says about the same tokens (from the training fwd):
logp = behav_logp + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, T))

# --- the paper's Listing 1: no forward pass, elementwise only -------------
prox_logp = compute_prox_logp_approximation(
    behav_logp, logp, versions, current_version, rl)
print("staleness d:", (current_version - versions).tolist())
print("prox sandwiched between behav/target:",
      bool(jnp.all((prox_logp >= jnp.minimum(behav_logp, logp) - 1e-6)
                   & (prox_logp <= jnp.maximum(behav_logp, logp) + 1e-6))))

# --- the Algorithm registry: every objective is a pluggable object --------
print("registered algorithms:", available())
advantages = jax.random.normal(jax.random.PRNGKey(2), (B, T))
mask = jnp.ones((B, T))
batch = LossInputs(advantages=advantages, mask=mask, behav_logp=behav_logp,
                   versions=versions, current_version=current_version)

algo = get_algorithm("a3po")  # fused-kernel A-3PO (alias: "loglinear")
loss, metrics = algo.loss(logp, batch, rl)
print(f"A-3PO loss: {float(loss):+.4f}  "
      f"iw in [{float(metrics['iw_min']):.3f}, "
      f"{float(metrics['iw_max']):.3f}]  "
      f"clipped: {int(metrics['clipped_tokens'])} tokens  "
      f"kl: {float(metrics['kl']):+.4f}")

# swapping the algorithm is one registry lookup — asympo needs no
# behavior logps at all (see `launch/train.py --algo list` for flags)
asympo = get_algorithm("asympo")
loss2, m2 = asympo.loss(
    logp, LossInputs(advantages=advantages, mask=mask), rl)
print(f"ASymPO loss (behavior-free): {float(loss2):+.4f}")
