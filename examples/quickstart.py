"""Quickstart: the A-3PO approximation in 30 lines.

Shows the paper's core idea standalone — approximate the proximal policy by
staleness-aware log-linear interpolation instead of a forward pass — and
plugs it into the decoupled PPO loss.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import RLConfig
from repro.core.a3po import compute_prox_logp_approximation
from repro.core.losses import policy_loss

B, T = 4, 16
key = jax.random.PRNGKey(0)
rl = RLConfig()

# what the rollout engine hands the trainer:
behav_logp = -jax.random.uniform(key, (B, T)) * 2       # log pi_behav
versions = jnp.array([0, 1, 2, 3])                      # behavior versions
current_version = 3                                     # v(pi_theta)

# what the live policy says about the same tokens (from the training fwd):
logp = behav_logp + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, T))

# --- the paper's Listing 1: no forward pass, elementwise only -------------
prox_logp = compute_prox_logp_approximation(
    behav_logp, logp, versions, current_version, rl)
print("staleness d:", (current_version - versions).tolist())
print("prox sandwiched between behav/target:",
      bool(jnp.all((prox_logp >= jnp.minimum(behav_logp, logp) - 1e-6)
                   & (prox_logp <= jnp.maximum(behav_logp, logp) + 1e-6))))

# --- full decoupled objective (Eq. 2) with the approximated anchor --------
advantages = jax.random.normal(jax.random.PRNGKey(2), (B, T))
mask = jnp.ones((B, T))
loss, metrics = policy_loss(
    "loglinear", logp, behav_logp, advantages, mask, rl,
    versions=versions, current_version=current_version)
print(f"A-3PO loss: {float(loss):+.4f}  "
      f"iw in [{float(metrics['iw_min']):.3f}, "
      f"{float(metrics['iw_max']):.3f}]  "
      f"clipped: {int(metrics['clipped_tokens'])} tokens")
