"""Beyond-paper ablation: alpha schedules for the prox approximation.

The paper fixes alpha = 1/d. We compare: inverse (paper), exp (gamma^d),
clipped inverse, and const — same SFT base, same data order — and report
final eval reward + stability stats for each. Each variant is just the
``A3PO`` Algorithm with a different nested ``schedule`` override — the
registry API makes an ablation a list of frozen Algorithm instances.

Run: PYTHONPATH=src python examples/ablate_alpha.py [--steps 25]
"""
import argparse
import dataclasses
import json
import os

import jax
import numpy as np

from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.core.algorithms import A3PO
from repro.async_rl.orchestrator import simulate_async
from repro.data.tasks import ArithmeticTask
from repro.training.optimizer import adam_init
from repro.training.trainer import TrainState
from benchmarks.bench_training import eval_reward, sft_warmup


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=25)
    p.add_argument("--staleness", type=int, default=3)
    args = p.parse_args()

    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    task = ArithmeticTask(max_operand=9, n_terms=2, prompt_len=8, seed=0)
    base_params, _ = sft_warmup(cfg, task)
    base = eval_reward(cfg, base_params, task)
    print(f"base eval reward {base:.3f}")

    results = {}
    for schedule in ("inverse", "exp", "clipped", "const"):
        # per-algorithm nested config: the schedule override lives on the
        # frozen A3PO instance, not in a parallel RLConfig field
        algo = A3PO(schedule=schedule)
        rl = RLConfig(algo=algo, group_size=4, num_minibatches=2,
                      learning_rate=2e-4)
        state = TrainState(base_params, adam_init(base_params),
                           jax.numpy.zeros((), jax.numpy.int32))
        state, recs = simulate_async(
            cfg, rl, task, algo, args.steps, n_prompts=8,
            max_new_tokens=6, staleness=args.staleness, seed=0,
            init_state=state)
        final = eval_reward(cfg, state.params, task)
        results[schedule] = {
            "final_eval": final,
            "iw_max": float(np.max([r.iw_max for r in recs])),
            "clipped_tokens_mean": float(np.mean(
                [r.clipped_tokens for r in recs])),
        }
        print(f"{schedule:8s}: eval {final:.3f} "
              f"iw_max {results[schedule]['iw_max']:.2f} "
              f"clip/step {results[schedule]['clipped_tokens_mean']:.1f}")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/alpha_ablation.json", "w") as f:
        json.dump({"base_eval": base, "staleness": args.staleness,
                   "results": results}, f, indent=2)
    print("saved experiments/alpha_ablation.json")


if __name__ == "__main__":
    main()
