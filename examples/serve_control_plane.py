"""Staleness-aware rollout control plane demo.

Serves two GRPO-style groups of repeated prompts plus one urgent request
through the control plane while weight versions are published mid-flight:

* the radix prefix cache turns each group's repeated prompt into one
  prefill (watch ``prefix_hit_rate``);
* a publish mid-generation does NOT drain or restart in-flight sequences —
  they resume under the new params and their tokens carry per-token
  version stamps (the ``[B, T]`` staleness signal A-3PO's alpha consumes);
* the admission scheduler runs priority classes and a staleness budget.

Run: PYTHONPATH=src python examples/serve_control_plane.py
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.async_rl.weights import WeightStore
from repro.configs.registry import get_config
from repro.data.tasks import ArithmeticTask
from repro.models import model as M
from repro.rollout.continuous import ContinuousBatchingEngine
from repro.serving import (
    AdmissionScheduler,
    SchedulerConfig,
    ServingControlPlane,
)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--group", type=int, default=4)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--publish-every", type=int, default=3,
                   help="steps between simulated weight publishes")
    args = p.parse_args()

    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    store = WeightStore(params, 0)
    engine = ContinuousBatchingEngine(cfg, max_seqs=args.slots, block_size=8,
                                      n_blocks=128, max_blocks_per_seq=8)
    cp = ServingControlPlane(
        engine, store, AdmissionScheduler(SchedulerConfig(d_max=8)))

    task = ArithmeticTask(max_operand=99, n_terms=2, prompt_len=12, seed=3)
    batch = task.sample(2)
    for i in range(2):  # two GRPO groups: group-size copies of each prompt
        L = int(batch.prompt_lengths[i])
        for _ in range(args.group):
            cp.submit(batch.prompts[i, :L], max_new=args.max_new, priority=1)
    urgent = task.sample(1)
    cp.submit(urgent.prompts[0, : int(urgent.prompt_lengths[0])],
              max_new=args.max_new, priority=0)  # jumps the bulk queue

    key = jax.random.PRNGKey(1)
    version = 0
    done = []
    steps = 0
    while len(done) < 2 * args.group + 1 and steps < 500:
        key, sub = jax.random.split(key)
        done.extend(cp.step(sub))
        steps += 1
        if steps % args.publish_every == 0:
            version += 1
            store.publish(params, version)  # trainer publish, mid-flight

    print(f"served {len(done)} requests in {steps} steps, "
          f"{version} weight publishes absorbed mid-flight")
    for r in done[: args.group + 1]:
        boundary = len(set(r.token_versions)) > 1
        print(f"  req{r.rid} prio={r.priority} prefix_hit="
              f"{r.prefix_hit_tokens}/{len(r.prompt)} "
              f"stamps={r.token_versions}"
              f"{'  <- crossed publish' if boundary else ''}")
    snap = cp.metrics.snapshot()
    keys = ("prefix_hit_rate", "prefill_tokens_computed", "decode_tokens",
            "interrupts", "resumed_sequences", "staleness_mean",
            "staleness_max", "page_util_mean", "completed")
    print("metrics:", {k: round(snap[k], 3) for k in keys})


if __name__ == "__main__":
    main()
