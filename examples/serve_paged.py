"""Continuous-batching serving over the paged KV cache (vLLM-style).

Requests of different lengths stream through a fixed number of slots;
pages are recycled as sequences finish. Compare with examples/serve_batch.py
(static batching, dense cache).

Run: PYTHONPATH=src python examples/serve_paged.py [--requests 12]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.data import tokenizer as tok
from repro.data.tasks import ArithmeticTask
from repro.models import model as M
from repro.rollout.continuous import ContinuousBatchingEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--horizon", type=int, default=8,
                   help="decode tokens per compiled launch (1 = per-token)")
    args = p.parse_args()

    cfg = dataclasses.replace(get_config("toy-2m"), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    srv = ContinuousBatchingEngine(cfg, max_seqs=args.slots, block_size=8,
                                   n_blocks=128, max_blocks_per_seq=8,
                                   greedy=True, decode_horizon=args.horizon)
    task = ArithmeticTask(max_operand=99, n_terms=2, prompt_len=12, seed=3)
    batch = task.sample(args.requests)
    for i in range(args.requests):
        L = int(batch.prompt_lengths[i])
        srv.submit(batch.prompts[i, :L], max_new=args.max_new)

    t0 = time.perf_counter()
    done = srv.run(params, jax.random.PRNGKey(1))
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done)
    print(f"{len(done)} requests through {args.slots} slots "
          f"(horizon {args.horizon}): {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s, {srv.host_syncs} host syncs)")
    for r in done[:4]:
        print(f"  req{r.rid}: {tok.decode(r.prompt)!r} -> "
              f"{tok.decode(r.generated)!r}")
    print(f"free pages after drain: {srv.allocator.n_free}")


if __name__ == "__main__":
    main()
