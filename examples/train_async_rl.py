"""End-to-end driver: asynchronous RL training with A-3PO on CPU.

Pipeline (mirrors the paper's setup at toy scale):
  1. SFT-warm a ~2M/20M-param decoder on the synthetic arithmetic task
     (the stand-in for an instruct base model).
  2. Run async RL — rollout engine + trainer decoupled, behavior policy
     lagging `--staleness` versions — with the chosen algorithm (any
     registry name: a3po / recompute / sync / asympo / grpo_mu / ...).
  3. Report reward curves, prox-computation time, stability stats, and a
     held-out greedy eval. Checkpoints saved under experiments/ckpt/.

Run: PYTHONPATH=src python examples/train_async_rl.py \
       --algo a3po --steps 40 [--model toy-20m] [--threaded]
"""
import argparse
import dataclasses
import json
import os

import jax
import numpy as np

from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.core.algorithms import resolve_algorithm
from repro.async_rl.orchestrator import AsyncOrchestrator, simulate_async
from repro.data.tasks import ArithmeticTask
from repro.training.checkpoints import save_checkpoint
from repro.training.optimizer import adam_init
from repro.training.trainer import TrainState, Trainer
from benchmarks.bench_training import eval_reward, sft_warmup


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--algo", default="a3po",
                   help="policy-optimization algorithm (registry name)")
    p.add_argument("--model", default="toy-2m")
    p.add_argument("--steps", type=int, default=40)
    p.add_argument("--staleness", type=int, default=2)
    p.add_argument("--sft-steps", type=int, default=150)
    p.add_argument("--prompts", type=int, default=8)
    p.add_argument("--threaded", action="store_true",
                   help="real thread-decoupled engines instead of the "
                        "deterministic simulator")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    algo = resolve_algorithm(args.algo)
    cfg = dataclasses.replace(get_config(args.model), dtype="float32")
    rl = RLConfig(algo=algo, group_size=4, num_minibatches=2,
                  learning_rate=2e-4)
    task = ArithmeticTask(max_operand=9, n_terms=2, prompt_len=8,
                          seed=args.seed)

    print(f"== SFT warmup ({args.sft_steps} steps, "
          f"{cfg.num_params()/1e6:.1f}M params) ==")
    params, sft_loss = sft_warmup(cfg, task, steps=args.sft_steps)
    base = eval_reward(cfg, params, task)
    print(f"base eval reward: {base:.3f} (sft loss {sft_loss:.3f})")

    state = TrainState(params, adam_init(params),
                       jax.numpy.zeros((), jax.numpy.int32))
    print(f"== async RL: algo={algo.name} staleness={args.staleness} ==")
    if args.threaded:
        orch = AsyncOrchestrator(cfg, rl, task, algo,
                                 n_prompts=args.prompts, max_new_tokens=6)
        state, recs = orch.run(state, args.steps)
    else:
        staleness = 0 if algo.on_policy else args.staleness
        state, recs = simulate_async(
            cfg, rl, task, algo, args.steps, n_prompts=args.prompts,
            max_new_tokens=6, staleness=staleness, seed=args.seed,
            init_state=state, eval_every=10,
            eval_fn=lambda p: eval_reward(cfg, p, task, n=32))

    for r in recs:
        if r.step % 5 == 0 or r.step == len(recs) - 1 or r.eval_reward is not None:
            ev = f" eval {r.eval_reward:.3f}" if r.eval_reward is not None else ""
            print(f"  step {r.step:3d} reward {r.reward:.3f} "
                  f"loss {r.loss:+.4f} entropy {r.entropy:.3f} "
                  f"prox {r.prox_time_s*1e3:.2f}ms "
                  f"stale {r.staleness_mean:.1f}{ev}")

    final = eval_reward(cfg, state.params, task)
    print(f"final eval reward: {final:.3f} (base {base:.3f})")
    out = os.path.join("experiments", "ckpt", f"{args.model}_{algo.name}")
    save_checkpoint(out, {"params": state.params},
                    {"algo": algo.name, "steps": args.steps,
                     "final_eval_reward": final})
    print(f"checkpoint: {out}.npz")
    summary = {"algo": algo.name, "base_eval": base, "final_eval": final,
               "mean_prox_ms": float(np.mean(
                   [r.prox_time_s for r in recs[1:]])) * 1e3}
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
