"""Batched serving example: continuous request handling with the rollout
engine (the inference half of the async system).

Submits several waves of prompts, generates with the KV-cached decode loop,
and reports tokens/s + per-request completions. ``--arch`` selects any
registry architecture (reduced variants keep it CPU-sized).

Run: PYTHONPATH=src python examples/serve_batch.py \
       [--arch toy-2m] [--waves 3] [--batch 8]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import RLConfig
from repro.configs.registry import get_config
from repro.data import tokenizer as tok
from repro.data.tasks import ArithmeticTask
from repro.models import model as M
from repro.rollout.engine import RolloutEngine


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="toy-2m")
    p.add_argument("--waves", type=int, default=3)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--max-new", type=int, default=8)
    args = p.parse_args()

    name = args.arch
    cfg = get_config(name)
    if cfg.num_params() > 5e7:  # big configs serve as reduced on CPU
        name += "-reduced"
        cfg = get_config(name)
    cfg = dataclasses.replace(cfg, dtype="float32")
    print(f"serving {name}: {cfg.num_params()/1e6:.1f}M params, "
          f"{cfg.arch_type}")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = RolloutEngine(cfg, RLConfig(temperature=0.8),
                           max_new_tokens=args.max_new)
    task = ArithmeticTask(max_operand=99, n_terms=2, prompt_len=12, seed=1)

    total_tokens, total_time = 0, 0.0
    for wave in range(args.waves):
        b = task.sample(args.batch)
        # clamp token ids into this arch's vocab (task vocab is tiny)
        prompts = np.minimum(b.prompts, cfg.vocab_size - 1)
        t0 = time.perf_counter()
        rb = engine.generate(params, prompts, b.prompt_lengths,
                             jax.random.PRNGKey(wave), version=wave)
        dt = time.perf_counter() - t0
        n_tok = int(rb.gen_mask.sum())
        total_tokens += n_tok
        total_time += dt
        print(f"wave {wave}: {args.batch} reqs, {n_tok} tokens in "
              f"{dt:.2f}s ({n_tok/dt:.1f} tok/s)")
        if cfg.vocab_size >= tok.VOCAB_SIZE:
            for i in range(min(2, args.batch)):
                comp = engine.completions(rb)[i]
                print(f"   req{i}: {tok.decode(prompts[i])!r} -> "
                      f"{tok.decode(comp)!r}")
    print(f"TOTAL: {total_tokens} tokens, "
          f"{total_tokens/max(total_time,1e-9):.1f} tok/s")


if __name__ == "__main__":
    main()
